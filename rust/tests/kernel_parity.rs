//! Kernel-layer parity contracts: explicit-SIMD vs scalar microkernels,
//! the triangular syrk vs the full Aᵀ·B product, and the round-robin
//! parallel Jacobi eigh vs the serial cyclic sweep — plus the eigh
//! counter accounting for pool-dispatched decompositions.
//!
//! ISA coverage: `active_isa()` is decided once per process, so one test
//! run exercises exactly one microkernel. CI runs this binary twice —
//! once plain (AVX2+FMA on x86_64 runners) and once under
//! `FMRI_ENCODE_FORCE_SCALAR=1` — so both dispatch arms are tested; the
//! explicit `kernel_4x8_with` parity test below compares the two kernels
//! directly inside a single process whenever the host supports both.

use std::sync::{Mutex, MutexGuard};

use fmri_encode::blas::micro::{
    self, active_isa, kernel_4x16_triangular_with, kernel_4x16_with, kernel_4x8_triangular_with,
    kernel_4x8_with, KernelIsa, MR, NR, NR_F32,
};
use fmri_encode::blas::{Backend, Blas};
use fmri_encode::cv::kfold;
use fmri_encode::linalg::{
    eigh_calls_this_thread, eigh_calls_total, jacobi_eigh, jacobi_eigh_parallel,
    reconstruction_error, Mat, MatF32, PARALLEL_EIGH_MIN_P,
};
use fmri_encode::ridge::{DesignPlan, LAMBDA_GRID};
use fmri_encode::util::pool::ThreadPool;
use fmri_encode::util::Pcg64;

/// Serialize tests that measure deltas of the process-wide eigh counter
/// (same discipline as tests/plan_parity.rs — separate binaries are
/// separate processes, so only this file's tests contend here).
static EIGH_LOCK: Mutex<()> = Mutex::new(());

fn serialize_eigh_counting() -> MutexGuard<'static, ()> {
    EIGH_LOCK.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

fn naive_at_a(x: &Mat) -> Mat {
    let p = x.cols();
    let mut k = Mat::zeros(p, p);
    for i in 0..p {
        for j in 0..p {
            let mut acc = 0.0;
            for r in 0..x.rows() {
                acc += x.get(r, i) * x.get(r, j);
            }
            k.set(i, j, acc);
        }
    }
    k
}

#[test]
fn simd_and_scalar_kernels_agree_on_odd_panels() {
    // The AVX2 kernel contracts each multiply-add with FMA, so its
    // roundoff differs from the scalar kernel by O(kb·ε) per output
    // element; with N(0,1) inputs and kb ≤ KC = 256 the difference is
    // far below 1e-10 absolute. Runs only where both kernels exist.
    #[cfg(target_arch = "x86_64")]
    {
        if !(std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma"))
        {
            eprintln!("skipping: host lacks AVX2+FMA");
            return;
        }
        let mut rng = Pcg64::seeded(21);
        for kb in [1, 2, 3, 7, 64, 117, 256] {
            let a = Mat::randn(MR, kb, &mut rng);
            let b = Mat::randn(kb, NR, &mut rng);
            let mut apack = vec![0.0; MR * kb];
            let mut bpack = vec![0.0; NR * kb];
            micro::pack_a(&a, 0, MR, 0, kb, &mut apack);
            micro::pack_b(&b, 0, kb, 0, NR, &mut bpack);
            // Non-zero starting accumulators so the spill path's
            // load-add-store is exercised too.
            let mut acc_scalar = [[0.5f64; NR]; MR];
            let mut acc_simd = [[0.5f64; NR]; MR];
            kernel_4x8_with(KernelIsa::Scalar, &apack, &bpack, kb, &mut acc_scalar);
            kernel_4x8_with(KernelIsa::Avx2Fma, &apack, &bpack, kb, &mut acc_simd);
            for r in 0..MR {
                for c in 0..NR {
                    let d = (acc_scalar[r][c] - acc_simd[r][c]).abs();
                    assert!(d < 1e-10, "kb={kb} ({r},{c}): diff {d}");
                }
            }
        }
    }
}

#[test]
fn simd_and_scalar_triangular_kernels_agree_and_mask_identically() {
    // The diagonal-straddling triangular tile: the AVX2 variant computes
    // full-width lanes in registers but must (a) match the scalar tile on
    // every accumulated lane within FMA-contraction roundoff, and (b)
    // leave masked lanes of the accumulator bit-exactly untouched.
    #[cfg(target_arch = "x86_64")]
    {
        if !(std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma"))
        {
            eprintln!("skipping: host lacks AVX2+FMA");
            return;
        }
        let mut rng = Pcg64::seeded(26);
        for kb in [1, 2, 3, 7, 64, 117, 256] {
            // Every diagonal geometry a straddling MR-strip can see:
            // staircase starts, full rows, fully masked rows.
            for lane_start in [[0, 1, 2, 3], [1, 2, 3, 4], [5, 6, 7, 8], [0, 0, 7, 8]] {
                for mrows in [1, 2, 4] {
                    let a = Mat::randn(MR, kb, &mut rng);
                    let b = Mat::randn(kb, NR, &mut rng);
                    let mut apack = vec![0.0; MR * kb];
                    let mut bpack = vec![0.0; NR * kb];
                    micro::pack_a(&a, 0, MR, 0, kb, &mut apack);
                    micro::pack_b(&b, 0, kb, 0, NR, &mut bpack);
                    // A sentinel accumulator so untouched lanes are provable.
                    let mut acc_scalar = [[0.5f64; NR]; MR];
                    let mut acc_simd = [[0.5f64; NR]; MR];
                    kernel_4x8_triangular_with(
                        KernelIsa::Scalar, &apack, &bpack, kb, &mut acc_scalar, mrows, &lane_start,
                    );
                    kernel_4x8_triangular_with(
                        KernelIsa::Avx2Fma, &apack, &bpack, kb, &mut acc_simd, mrows, &lane_start,
                    );
                    for r in 0..MR {
                        for c in 0..NR {
                            let masked = r >= mrows || c < lane_start[r].min(NR);
                            if masked {
                                assert_eq!(
                                    acc_simd[r][c], 0.5,
                                    "kb={kb} mrows={mrows} ({r},{c}): masked lane written"
                                );
                                assert_eq!(acc_scalar[r][c], 0.5);
                            } else {
                                let d = (acc_scalar[r][c] - acc_simd[r][c]).abs();
                                assert!(d < 1e-10, "kb={kb} mrows={mrows} ({r},{c}): diff {d}");
                            }
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn f32_simd_and_scalar_kernels_agree_on_odd_panels() {
    // The f32 kernel runs 2×16-lane FMA at double the f64 lane count, so
    // its contraction roundoff against the scalar kernel is O(kb·ε_f32)
    // per output — with N(0,1) inputs and kb ≤ KC = 256 that is ~1e-4
    // absolute; 1e-3 is the documented bound. Runs only where both
    // kernels exist.
    #[cfg(target_arch = "x86_64")]
    {
        if !(std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma"))
        {
            eprintln!("skipping: host lacks AVX2+FMA");
            return;
        }
        let mut rng = Pcg64::seeded(41);
        for kb in [1, 2, 3, 7, 64, 117, 256] {
            let a = MatF32::from_f64(&Mat::randn(MR, kb, &mut rng));
            let b = MatF32::from_f64(&Mat::randn(kb, NR_F32, &mut rng));
            let mut apack = vec![0.0f32; MR * kb];
            let mut bpack = vec![0.0f32; NR_F32 * kb];
            micro::pack_a_e(&a, 0, MR, 0, kb, &mut apack);
            micro::pack_b_e(&b, 0, kb, 0, NR_F32, &mut bpack);
            // Non-zero starting accumulators so the spill path's
            // load-add-store is exercised too.
            let mut acc_scalar = [[0.5f32; NR_F32]; MR];
            let mut acc_simd = [[0.5f32; NR_F32]; MR];
            kernel_4x16_with(KernelIsa::Scalar, &apack, &bpack, kb, &mut acc_scalar);
            kernel_4x16_with(KernelIsa::Avx2Fma, &apack, &bpack, kb, &mut acc_simd);
            for r in 0..MR {
                for c in 0..NR_F32 {
                    let d = (acc_scalar[r][c] - acc_simd[r][c]).abs();
                    assert!(d < 1e-3, "kb={kb} ({r},{c}): diff {d}");
                }
            }
        }
    }
}

#[test]
fn f32_triangular_kernels_agree_and_mask_identically() {
    // Same contract as the f64 triangular tile, at 16 lanes: accumulated
    // lanes agree within f32 FMA-contraction roundoff, masked lanes stay
    // bit-exactly untouched.
    #[cfg(target_arch = "x86_64")]
    {
        if !(std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma"))
        {
            eprintln!("skipping: host lacks AVX2+FMA");
            return;
        }
        let mut rng = Pcg64::seeded(42);
        for kb in [1, 3, 64, 117, 256] {
            // Diagonal geometries spanning both 8-lane registers of the
            // 16-wide strip: staircase starts, full rows, masked rows.
            for lane_start in [[0, 1, 2, 3], [5, 6, 7, 8], [13, 14, 15, 16], [0, 0, 15, 16]] {
                for mrows in [1, 2, 4] {
                    let a = MatF32::from_f64(&Mat::randn(MR, kb, &mut rng));
                    let b = MatF32::from_f64(&Mat::randn(kb, NR_F32, &mut rng));
                    let mut apack = vec![0.0f32; MR * kb];
                    let mut bpack = vec![0.0f32; NR_F32 * kb];
                    micro::pack_a_e(&a, 0, MR, 0, kb, &mut apack);
                    micro::pack_b_e(&b, 0, kb, 0, NR_F32, &mut bpack);
                    let mut acc_scalar = [[0.5f32; NR_F32]; MR];
                    let mut acc_simd = [[0.5f32; NR_F32]; MR];
                    kernel_4x16_triangular_with(
                        KernelIsa::Scalar, &apack, &bpack, kb, &mut acc_scalar, mrows, &lane_start,
                    );
                    kernel_4x16_triangular_with(
                        KernelIsa::Avx2Fma, &apack, &bpack, kb, &mut acc_simd, mrows, &lane_start,
                    );
                    for r in 0..MR {
                        for c in 0..NR_F32 {
                            let masked = r >= mrows || c < lane_start[r].min(NR_F32);
                            if masked {
                                assert_eq!(
                                    acc_simd[r][c], 0.5,
                                    "kb={kb} mrows={mrows} ({r},{c}): masked lane written"
                                );
                                assert_eq!(acc_scalar[r][c], 0.5);
                            } else {
                                let d = (acc_scalar[r][c] - acc_simd[r][c]).abs();
                                assert!(d < 1e-3, "kb={kb} mrows={mrows} ({r},{c}): diff {d}");
                            }
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn forced_scalar_override_is_respected() {
    // Under FMRI_ENCODE_FORCE_SCALAR the dispatcher must pin the scalar
    // kernel even on AVX2 hosts (CI's second run asserts this arm).
    if std::env::var_os("FMRI_ENCODE_FORCE_SCALAR").is_some() {
        assert_eq!(active_isa(), KernelIsa::Scalar);
    }
}

#[test]
fn all_tiers_match_naive_gemm_at_odd_shapes_under_active_isa() {
    // Whatever kernel active_isa() picked, every backend tier must agree
    // with the naive oracle at shapes straddling MR/NR/MC/KC edges, at
    // one and several threads.
    let mut rng = Pcg64::seeded(22);
    for (m, k, n) in [(5, 3, 9), (67, 130, 33), (129, 257, 41)] {
        let a = Mat::randn(m, k, &mut rng);
        let b = Mat::randn(k, n, &mut rng);
        let want = Blas::new(Backend::Naive, 1).gemm(&a, &b);
        for backend in [Backend::OpenBlasLike, Backend::MklLike] {
            for threads in [1, 4] {
                let got = Blas::new(backend, threads).gemm(&a, &b);
                let d = want.max_abs_diff(&got);
                assert!(d < 1e-10, "{backend:?} t={threads} ({m},{k},{n}): {d}");
            }
        }
    }
}

#[test]
fn at_b_all_tiers_match_transpose_oracle() {
    // The MKL-like tier's Aᵀ·B now runs the packed microkernel path
    // (pack_at); all tiers must still match Xᵀ·Y computed explicitly.
    let mut rng = Pcg64::seeded(23);
    let x = Mat::randn(90, 141, &mut rng);
    let y = Mat::randn(90, 37, &mut rng);
    let want = Blas::new(Backend::Naive, 1).gemm(&x.transpose(), &y);
    for backend in [Backend::Naive, Backend::OpenBlasLike, Backend::MklLike] {
        for threads in [1, 3] {
            let got = Blas::new(backend, threads).at_b(&x, &y);
            let d = want.max_abs_diff(&got);
            assert!(d < 1e-10, "{backend:?} t={threads}: {d}");
        }
    }
}

#[test]
fn triangular_syrk_matches_at_b_product() {
    // syrk computes only upper tiles and mirrors; it must match the full
    // Aᵀ·A to roundoff, be exactly symmetric, and be bit-stable across
    // thread counts — at sizes spanning the SYRK_TILE boundary.
    let mut rng = Pcg64::seeded(24);
    for p in [9, Blas::SYRK_TILE, Blas::SYRK_TILE + 31, 2 * Blas::SYRK_TILE + 5] {
        let x = Mat::randn(64, p, &mut rng);
        let want = naive_at_a(&x);
        for backend in [Backend::Naive, Backend::OpenBlasLike, Backend::MklLike] {
            let k1 = Blas::new(backend, 1).syrk(&x);
            let d = k1.max_abs_diff(&want);
            assert!(d < 1e-9, "{backend:?} p={p}: {d}");
            for i in 0..p {
                for j in 0..p {
                    assert_eq!(k1.get(i, j), k1.get(j, i), "{backend:?} p={p}");
                }
            }
            for threads in [2, 5] {
                let kt = Blas::new(backend, threads).syrk(&x);
                assert_eq!(k1.max_abs_diff(&kt), 0.0, "{backend:?} p={p} t={threads}");
            }
        }
    }
}

#[test]
fn f32_all_tiers_match_f64_oracle_and_are_thread_stable() {
    // The f32 instantiation of every backend tier must track the f64
    // product of the same (already f32-truncated) inputs within
    // accumulation roundoff — O(k·ε_f32) ≈ 1e-4 at k = 257 with N(0,1)
    // data; 1e-3 documented — and must be BIT-stable across thread
    // counts (the chunking never changes per-element accumulation
    // order, at either dtype).
    let mut rng = Pcg64::seeded(43);
    for (m, k, n) in [(5, 3, 9), (67, 130, 33), (129, 257, 41)] {
        let a32 = MatF32::from_f64(&Mat::randn(m, k, &mut rng));
        let b32 = MatF32::from_f64(&Mat::randn(k, n, &mut rng));
        let want = Blas::new(Backend::Naive, 1).gemm(&a32.to_f64(), &b32.to_f64());
        for backend in [Backend::Naive, Backend::OpenBlasLike, Backend::MklLike] {
            let c1 = Blas::new(backend, 1).gemm(&a32, &b32);
            let d = c1.to_f64().max_abs_diff(&want);
            assert!(d < 1e-3, "{backend:?} ({m},{k},{n}): {d}");
            for threads in [2, 4] {
                let ct = Blas::new(backend, threads).gemm(&a32, &b32);
                assert_eq!(c1.max_abs_diff(&ct), 0.0, "{backend:?} t={threads} not bit-stable");
            }
        }
    }
}

#[test]
fn f32_triangular_syrk_is_exactly_symmetric_and_thread_stable() {
    // The mirrored lower triangle makes symmetry EXACT (bitwise), and
    // tile-origin-keyed masking keeps the result bit-stable across
    // thread counts — both contracts are dtype-independent.
    let mut rng = Pcg64::seeded(44);
    for p in [9, Blas::SYRK_TILE, Blas::SYRK_TILE + 31] {
        let x32 = MatF32::from_f64(&Mat::randn(64, p, &mut rng));
        let want = naive_at_a(&x32.to_f64());
        for backend in [Backend::Naive, Backend::OpenBlasLike, Backend::MklLike] {
            let k1 = Blas::new(backend, 1).syrk(&x32);
            let d = k1.to_f64().max_abs_diff(&want);
            assert!(d < 1e-3, "{backend:?} p={p}: {d}");
            for i in 0..p {
                for j in 0..p {
                    assert_eq!(k1.get(i, j), k1.get(j, i), "{backend:?} p={p}");
                }
            }
            for threads in [2, 5] {
                let kt = Blas::new(backend, threads).syrk(&x32);
                assert_eq!(k1.max_abs_diff(&kt), 0.0, "{backend:?} p={p} t={threads}");
            }
        }
    }
}

#[test]
fn syrk_flop_count_is_exactly_the_upper_triangle() {
    // The multiply counter is thread-local and a 1-thread Blas runs all
    // kernel work inline on the calling thread, so this test observes
    // exactly its own kernels (the harness gives each test its own
    // thread).
    let mut rng = Pcg64::seeded(25);
    let n = 40;
    let blas = Blas::new(Backend::MklLike, 1);

    // One full diagonal tile, no MR/NR padding: the triangular diagonal
    // kernel must issue *exactly* the upper-triangle multiplies —
    // n·p(p+1)/2, not a strip-rounded approximation.
    let p = Blas::SYRK_TILE;
    let x = Mat::randn(n, p, &mut rng);
    micro::reset_kernel_muls();
    let k = blas.syrk(&x);
    let syrk_muls = micro::kernel_muls();
    assert_eq!(syrk_muls, (n * p * (p + 1) / 2) as u64);

    // Reference: the full AᵀB Gram issues n·p² (again no padding at
    // these sizes). The symmetric kernel saves just under half, and the
    // two results still agree to roundoff.
    micro::reset_kernel_muls();
    let kfull = blas.at_b(&x, &x);
    let full_muls = micro::kernel_muls();
    assert_eq!(full_muls, (n * p * p) as u64);
    assert!(syrk_muls < full_muls);
    assert!(k.max_abs_diff(&kfull) < 1e-9);

    // Multi-tile p with a ragged edge (diagonal tiles, off-diagonal
    // tiles, NR padding): the exact count no longer closes, but the
    // total must stay well under 60% of the full product's.
    let p2 = 2 * Blas::SYRK_TILE + 5;
    let x2 = Mat::randn(n, p2, &mut rng);
    micro::reset_kernel_muls();
    let _ = blas.syrk(&x2);
    let syrk2 = micro::kernel_muls();
    micro::reset_kernel_muls();
    let _ = blas.at_b(&x2, &x2);
    let full2 = micro::kernel_muls();
    assert!(syrk2 * 100 < full2 * 60, "syrk {syrk2} muls vs full {full2}");
}

fn spd(n: usize, p: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::seeded(seed);
    let x = Mat::randn(n, p, &mut rng);
    Blas::new(Backend::MklLike, 1).syrk(&x)
}

#[test]
fn parallel_eigh_matches_serial_above_dispatch_threshold() {
    let _guard = serialize_eigh_counting();
    let p = PARALLEL_EIGH_MIN_P + 22; // 150: the auto-dispatch regime
    let k = spd(2 * p, p, 31);
    let serial = jacobi_eigh(&k, 30, 1e-12);
    let pool = ThreadPool::new(4);
    let par = jacobi_eigh_parallel(&k, 30, 1e-12, &pool);
    for (a, b) in par.values.iter().zip(&serial.values) {
        assert!((a - b).abs() < 1e-8 * (1.0 + b.abs()), "{a} vs {b}");
    }
    let err = reconstruction_error(&k, &par.values, &par.vectors);
    assert!(err < 1e-9, "reconstruction err {err}");

    // Blas::eigh at this size on a multi-thread pool takes the parallel
    // path; the result must be the same decomposition.
    let via_blas = Blas::new(Backend::MklLike, 4).eigh(&k, 30, 1e-12);
    assert_eq!(via_blas.values, par.values);
    assert_eq!(via_blas.vectors.max_abs_diff(&par.vectors), 0.0);
}

#[test]
fn parallel_eigh_handles_ill_conditioned_spectrum() {
    let _guard = serialize_eigh_counting();
    // Spectrum spanning 10 orders of magnitude at parallel-dispatch size.
    let p = PARALLEL_EIGH_MIN_P + 5;
    let mut rng = Pcg64::seeded(32);
    let q = gram_schmidt(&Mat::randn(p, p, &mut rng));
    let evals: Vec<f64> = (0..p)
        .map(|i| 10f64.powf(-5.0 + 10.0 * i as f64 / (p - 1) as f64))
        .collect();
    let mut k = Mat::zeros(p, p);
    for i in 0..p {
        for j in 0..p {
            let mut acc = 0.0;
            for l in 0..p {
                acc += q.get(i, l) * evals[l] * q.get(j, l);
            }
            k.set(i, j, acc);
        }
    }
    let pool = ThreadPool::new(4);
    let d = jacobi_eigh_parallel(&k, 30, 1e-13, &pool);
    assert!(reconstruction_error(&k, &d.values, &d.vectors) < 1e-9);
    for w in d.values.windows(2) {
        assert!(w[0] <= w[1], "eigenvalues not ascending");
    }
}

#[test]
fn f32_eigh_handles_ill_conditioned_spectrum() {
    let _guard = serialize_eigh_counting();
    // The same 10-decade spectrum through the f32 entry point. The
    // promote-solve-demote policy rotates in f64, so convergence is the
    // f64 Jacobi's; accuracy is then bounded by the single demotion of
    // the result (and the initial f32 truncation of K): errors scale as
    // ε_f32·λ_max ≈ 1e-2 here. Eigenvalues below that noise floor are
    // unrecoverable at this dtype — exactly the documented trade.
    let p = PARALLEL_EIGH_MIN_P + 5;
    let mut rng = Pcg64::seeded(45);
    let q = gram_schmidt(&Mat::randn(p, p, &mut rng));
    let evals: Vec<f64> = (0..p)
        .map(|i| 10f64.powf(-5.0 + 10.0 * i as f64 / (p - 1) as f64))
        .collect();
    let lambda_max = evals[p - 1];
    let mut k = Mat::zeros(p, p);
    for i in 0..p {
        for j in 0..p {
            let mut acc = 0.0;
            for l in 0..p {
                acc += q.get(i, l) * evals[l] * q.get(j, l);
            }
            k.set(i, j, acc);
        }
    }
    let k32 = MatF32::from_f64(&k);
    let d = Blas::new(Backend::MklLike, 4).eigh(&k32, 30, 1e-13);
    let vals: Vec<f64> = d.values.iter().map(|&v| v as f64).collect();
    // `reconstruction_error` is relative (Frobenius ratio), so the f32
    // demotion's ε_f32·√p shows up directly: ~1e-6 here, 1e-5 bound.
    let err = reconstruction_error(&k32.to_f64(), &vals, &d.vectors.to_f64());
    assert!(err < 1e-5, "reconstruction err {err}");
    for w in d.values.windows(2) {
        assert!(w[0] <= w[1], "eigenvalues not ascending");
    }
    // The top of the spectrum survives the precision trade intact.
    assert!(
        (vals[p - 1] - lambda_max).abs() < 1e-4 * lambda_max,
        "λmax {} vs {lambda_max}",
        vals[p - 1]
    );
}

fn gram_schmidt(m: &Mat) -> Mat {
    let p = m.rows();
    let mut q = m.clone();
    for j in 0..p {
        for prev in 0..j {
            let dot: f64 = (0..p).map(|i| q.get(i, j) * q.get(i, prev)).sum();
            for i in 0..p {
                let v = q.get(i, j) - dot * q.get(i, prev);
                q.set(i, j, v);
            }
        }
        let norm: f64 = (0..p).map(|i| q.get(i, j).powi(2)).sum::<f64>().sqrt();
        for i in 0..p {
            let v = q.get(i, j) / norm;
            q.set(i, j, v);
        }
    }
    q
}

#[test]
fn pool_threaded_eigh_counts_exactly_once() {
    let _guard = serialize_eigh_counting();
    // A parallel eigh fans rotation work across the pool but is ONE
    // decomposition: both counters move by exactly 1, and the increment
    // lands on the calling thread (workers never touch the thread-local).
    let p = PARALLEL_EIGH_MIN_P + 2;
    let k = spd(2 * p, p, 33);
    let blas = Blas::new(Backend::MklLike, 4);
    let total_before = eigh_calls_total();
    let local_before = eigh_calls_this_thread();
    let _ = blas.eigh(&k, 30, 1e-12);
    assert_eq!(eigh_calls_total() - total_before, 1);
    assert_eq!(eigh_calls_this_thread() - local_before, 1);
}

#[test]
fn plan_eigh_count_pin_holds_with_multithreaded_blas() {
    let _guard = serialize_eigh_counting();
    // The decompose-once contract must survive the Blas-pool eigh
    // dispatch: a plan build on a 4-thread Blas still costs exactly
    // splits + 1 decompositions, counted on the building thread.
    let mut rng = Pcg64::seeded(34);
    let x = Mat::randn(80, 10, &mut rng);
    let splits = kfold(80, 3, Some(0));
    let blas = Blas::new(Backend::MklLike, 4);
    let before = eigh_calls_this_thread();
    let plan = DesignPlan::build(&blas, &x, &LAMBDA_GRID, &splits);
    assert_eq!(eigh_calls_this_thread() - before, splits.len() + 1);
    assert_eq!(plan.decompositions(), splits.len() + 1);
}
