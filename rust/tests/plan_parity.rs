//! Plan/execute contract tests: the shared `DesignPlan` performs exactly
//! one eigendecomposition per CV split (+1 full-train) no matter how many
//! batches execute against it, batch fits do none at all, and the planned
//! coordinator path reproduces the pre-refactor per-batch weights to
//! roundoff.
//!
//! Counting discipline: `DesignPlan::build` is serial on the calling
//! thread, so its contract uses the thread-local counter. The
//! coordinator's B-MOR decompose stage runs its factorizations as
//! parallel graph tasks on worker threads, so its contract uses the
//! process-wide counter — and every test in this binary grabs `EIGH_LOCK`
//! so concurrently scheduled tests cannot perturb the global deltas
//! (other test binaries are separate processes).

use std::sync::{Mutex, MutexGuard};

use fmri_encode::blas::{Backend, Blas};
use fmri_encode::coordinator::{self, batch_bounds, DistConfig, Strategy};
use fmri_encode::cv::kfold;
use fmri_encode::linalg::{eigh_calls_this_thread, eigh_calls_total, Mat};
use fmri_encode::ridge::{self, DesignPlan, LAMBDA_GRID};
use fmri_encode::util::Pcg64;

static EIGH_LOCK: Mutex<()> = Mutex::new(());

fn serialize_eigh_counting() -> MutexGuard<'static, ()> {
    EIGH_LOCK.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

fn planted(n: usize, p: usize, t: usize, seed: u64) -> (Mat, Mat) {
    let mut rng = Pcg64::seeded(seed);
    let x = Mat::randn(n, p, &mut rng);
    let w = Mat::randn(p, t, &mut rng);
    let blas = Blas::new(Backend::MklLike, 1);
    let mut y = blas.gemm(&x, &w);
    for v in y.data_mut() {
        *v += 0.3 * rng.normal();
    }
    (x, y)
}

#[test]
fn plan_decomposes_once_regardless_of_batch_count() {
    let _guard = serialize_eigh_counting();
    // The serial build runs on this thread, so the thread-local counter
    // pins it exactly.
    let (x, y) = planted(90, 12, 16, 1);
    let splits = kfold(90, 3, Some(0));
    let blas = Blas::new(Backend::MklLike, 1);

    let before = eigh_calls_this_thread();
    let plan = DesignPlan::build(&blas, &x, &LAMBDA_GRID, &splits);
    let after_build = eigh_calls_this_thread();
    assert_eq!(
        after_build - before,
        splits.len() + 1,
        "plan build must cost exactly splits+1 eigendecompositions"
    );
    assert_eq!(plan.decompositions(), splits.len() + 1);

    // Fan out every batch count from 1 to 16: ZERO further
    // eigendecompositions, total stays splits+1.
    for batches in [1, 2, 4, 8, 16] {
        for (j0, j1) in batch_bounds(16, batches) {
            let yb = y.cols_slice(j0, j1);
            let _ = ridge::fit_batch_with_plan(&blas, &plan, &yb);
        }
        assert_eq!(
            eigh_calls_this_thread(),
            after_build,
            "batch sweep performed an eigendecomposition at {batches} batches"
        );
    }
}

#[test]
fn bmor_fit_decomposes_exactly_splits_plus_one_times() {
    let _guard = serialize_eigh_counting();
    // `coordinator::fit` now runs the decompose stage as parallel graph
    // tasks on worker threads (one factorization per split + the full
    // train), so the contract is on the PROCESS-WIDE counter: the whole
    // distributed fit costs exactly inner_folds + 1 eigendecompositions,
    // no matter how many nodes fan the sweep out.
    let (x, y) = planted(100, 10, 12, 2);
    for nodes in [1, 3, 6] {
        let cfg = DistConfig {
            strategy: Strategy::Bmor,
            nodes,
            ..Default::default()
        };
        let before = eigh_calls_total();
        let leader_before = eigh_calls_this_thread();
        let fit = coordinator::fit(&x, &y, &cfg);
        let delta = eigh_calls_total() - before;
        assert_eq!(
            delta,
            cfg.inner_folds + 1,
            "nodes={nodes}: fit performed {delta} decompositions"
        );
        // The leader thread itself decomposes nothing: every factorization
        // lives in a graph task on a worker thread.
        assert_eq!(
            eigh_calls_this_thread(),
            leader_before,
            "nodes={nodes}: leader thread performed an eigendecomposition"
        );
        assert_eq!(fit.batches.len(), nodes.min(12));
        assert!(fit.plan_secs > 0.0);
    }
}

#[test]
fn planned_bmor_matches_per_batch_reference_weights() {
    let _guard = serialize_eigh_counting();
    // Acceptance: coordinator::fit(Bmor) must match the pre-refactor path
    // (each batch decomposing from scratch via fit_ridge_cv_unshared) to
    // 1e-10, for several batch counts.
    let (x, y) = planted(120, 12, 18, 3);
    let blas = Blas::new(Backend::MklLike, 1);
    for nodes in [1, 2, 4, 6] {
        let cfg = DistConfig {
            strategy: Strategy::Bmor,
            nodes,
            ..Default::default()
        };
        let fit = coordinator::fit(&x, &y, &cfg);
        let splits = kfold(x.rows(), cfg.inner_folds, Some(cfg.seed));
        for (bi, &(j0, j1)) in fit.batches.iter().enumerate() {
            let yb = y.cols_slice(j0, j1);
            let reference = ridge::fit_ridge_cv_unshared(&blas, &x, &yb, &LAMBDA_GRID, &splits);
            assert_eq!(
                fit.best_lambda_per_batch[bi], reference.best_lambda,
                "nodes={nodes} batch={bi}: λ* diverged"
            );
            let wb = fit.weights.cols_slice(j0, j1);
            let diff = wb.max_abs_diff(&reference.weights);
            assert!(
                diff < 1e-10,
                "nodes={nodes} batch={bi}: weight diff {diff}"
            );
        }
    }
}

#[test]
fn wrapper_and_plan_reuse_agree_for_mor_batches() {
    let _guard = serialize_eigh_counting();
    // One-column batches (MOR degenerate case) through the shared plan
    // equal one-column fits through the thin wrapper.
    let (x, y) = planted(70, 8, 6, 4);
    let splits = kfold(70, 2, Some(1));
    let blas = Blas::new(Backend::MklLike, 1);
    let plan = DesignPlan::build(&blas, &x, &LAMBDA_GRID, &splits);
    for j in 0..6 {
        let yj = y.cols_slice(j, j + 1);
        let a = ridge::fit_batch_with_plan(&blas, &plan, &yj);
        let b = ridge::fit_ridge_cv(&blas, &x, &yj, &LAMBDA_GRID, &splits);
        assert_eq!(a.best_idx, b.best_idx, "target {j}");
        assert!(a.weights.max_abs_diff(&b.weights) < 1e-12, "target {j}");
    }
}
