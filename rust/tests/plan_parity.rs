//! Plan/execute contract tests: the shared `DesignPlan` performs exactly
//! one eigendecomposition per CV split (+1 full-train) no matter how many
//! batches execute against it, batch fits do none at all, and the planned
//! coordinator path reproduces the pre-refactor per-batch weights to
//! roundoff.

use fmri_encode::blas::{Backend, Blas};
use fmri_encode::coordinator::{self, batch_bounds, DistConfig, Strategy};
use fmri_encode::cv::kfold;
use fmri_encode::linalg::{eigh_calls_this_thread, Mat};
use fmri_encode::ridge::{self, DesignPlan, LAMBDA_GRID};
use fmri_encode::util::Pcg64;

fn planted(n: usize, p: usize, t: usize, seed: u64) -> (Mat, Mat) {
    let mut rng = Pcg64::seeded(seed);
    let x = Mat::randn(n, p, &mut rng);
    let w = Mat::randn(p, t, &mut rng);
    let blas = Blas::new(Backend::MklLike, 1);
    let mut y = blas.gemm(&x, &w);
    for v in y.data_mut() {
        *v += 0.3 * rng.normal();
    }
    (x, y)
}

#[test]
fn plan_decomposes_once_regardless_of_batch_count() {
    // The eigh counter is thread-local and this test drives plan + batch
    // fits on its own thread, so concurrent tests cannot perturb it.
    let (x, y) = planted(90, 12, 16, 1);
    let splits = kfold(90, 3, Some(0));
    let blas = Blas::new(Backend::MklLike, 1);

    let before = eigh_calls_this_thread();
    let plan = DesignPlan::build(&blas, &x, &LAMBDA_GRID, &splits);
    let after_build = eigh_calls_this_thread();
    assert_eq!(
        after_build - before,
        splits.len() + 1,
        "plan build must cost exactly splits+1 eigendecompositions"
    );
    assert_eq!(plan.decompositions(), splits.len() + 1);

    // Fan out every batch count from 1 to 16: ZERO further
    // eigendecompositions, total stays splits+1.
    for batches in [1, 2, 4, 8, 16] {
        for (j0, j1) in batch_bounds(16, batches) {
            let yb = y.cols_slice(j0, j1);
            let _ = ridge::fit_batch_with_plan(&blas, &plan, &yb);
        }
        assert_eq!(
            eigh_calls_this_thread(),
            after_build,
            "batch sweep performed an eigendecomposition at {batches} batches"
        );
    }
}

#[test]
fn coordinator_builds_exactly_one_plan_on_the_leader() {
    // `coordinator::fit` decomposes on the calling thread (plan build) and
    // its workers run on spawned threads doing sweep-only work — so the
    // caller-thread delta is exactly inner_folds+1 regardless of nodes.
    let (x, y) = planted(100, 10, 12, 2);
    for nodes in [1, 3, 6] {
        let cfg = DistConfig {
            strategy: Strategy::Bmor,
            nodes,
            ..Default::default()
        };
        let before = eigh_calls_this_thread();
        let fit = coordinator::fit(&x, &y, &cfg);
        let delta = eigh_calls_this_thread() - before;
        assert_eq!(
            delta,
            cfg.inner_folds + 1,
            "nodes={nodes}: leader performed {delta} decompositions"
        );
        assert_eq!(fit.batches.len(), nodes.min(12));
    }
}

#[test]
fn planned_bmor_matches_per_batch_reference_weights() {
    // Acceptance: coordinator::fit(Bmor) must match the pre-refactor path
    // (each batch decomposing from scratch via fit_ridge_cv_unshared) to
    // 1e-10, for several batch counts.
    let (x, y) = planted(120, 12, 18, 3);
    let blas = Blas::new(Backend::MklLike, 1);
    for nodes in [1, 2, 4, 6] {
        let cfg = DistConfig {
            strategy: Strategy::Bmor,
            nodes,
            ..Default::default()
        };
        let fit = coordinator::fit(&x, &y, &cfg);
        let splits = kfold(x.rows(), cfg.inner_folds, Some(cfg.seed));
        for (bi, &(j0, j1)) in fit.batches.iter().enumerate() {
            let yb = y.cols_slice(j0, j1);
            let reference = ridge::fit_ridge_cv_unshared(&blas, &x, &yb, &LAMBDA_GRID, &splits);
            assert_eq!(
                fit.best_lambda_per_batch[bi], reference.best_lambda,
                "nodes={nodes} batch={bi}: λ* diverged"
            );
            let wb = fit.weights.cols_slice(j0, j1);
            let diff = wb.max_abs_diff(&reference.weights);
            assert!(
                diff < 1e-10,
                "nodes={nodes} batch={bi}: weight diff {diff}"
            );
        }
    }
}

#[test]
fn wrapper_and_plan_reuse_agree_for_mor_batches() {
    // One-column batches (MOR degenerate case) through the shared plan
    // equal one-column fits through the thin wrapper.
    let (x, y) = planted(70, 8, 6, 4);
    let splits = kfold(70, 2, Some(1));
    let blas = Blas::new(Backend::MklLike, 1);
    let plan = DesignPlan::build(&blas, &x, &LAMBDA_GRID, &splits);
    for j in 0..6 {
        let yj = y.cols_slice(j, j + 1);
        let a = ridge::fit_batch_with_plan(&blas, &plan, &yj);
        let b = ridge::fit_ridge_cv(&blas, &x, &yj, &LAMBDA_GRID, &splits);
        assert_eq!(a.best_idx, b.best_idx, "target {j}");
        assert!(a.weights.max_abs_diff(&b.weights) < 1e-12, "target {j}");
    }
}
