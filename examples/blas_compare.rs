//! BLAS backend shoot-out (the Fig. 6 mechanism, measured for real):
//! naive vs OpenBLAS-like vs MKL-like GEMM on ridge-shaped products,
//! single thread, plus a multi-worker thread-pool demonstration.
//!
//! ```bash
//! cargo run --release --example blas_compare
//! ```

use fmri_encode::blas::{Backend, Blas};
use fmri_encode::linalg::Mat;
use fmri_encode::util::{timer, Pcg64};

fn main() {
    println!("== native GEMM backends (single thread) ==");
    let mut rng = Pcg64::seeded(0);
    // Ridge-shaped products: (p×n)(n×t) at parcels/ROI-ish repro sizes.
    let cases = [
        ("gram p=256 n=1024", 256, 1024, 256),
        ("sweep nv=400 p=512 t=444", 400, 512, 444),
        ("solve p=512 t=1024", 512, 512, 1024),
    ];
    println!(
        "{:<28} {:>12} {:>14} {:>12} {:>8}",
        "case", "naive", "openblas-like", "mkl-like", "mkl/ob"
    );
    for (name, m, k, n) in cases {
        let a = Mat::randn(m, k, &mut rng);
        let b = Mat::randn(k, n, &mut rng);
        let flops = 2.0 * (m * k * n) as f64;
        let mut gfs = vec![];
        for backend in [Backend::Naive, Backend::OpenBlasLike, Backend::MklLike] {
            let blas = Blas::new(backend, 1);
            let stats = timer::bench_adaptive(1, 0.4, 12, || {
                std::hint::black_box(blas.gemm(&a, &b));
            });
            gfs.push(flops / stats.median() / 1e9);
        }
        println!(
            "{:<28} {:>9.2} GF {:>11.2} GF {:>9.2} GF {:>7.2}×",
            name, gfs[0], gfs[1], gfs[2], gfs[2] / gfs[1]
        );
    }

    println!("\n== thread pool sanity (results identical across widths) ==");
    let a = Mat::randn(300, 200, &mut rng);
    let b = Mat::randn(200, 150, &mut rng);
    let ref_c = Blas::new(Backend::MklLike, 1).gemm(&a, &b);
    for threads in [2, 4, 8] {
        let c = Blas::new(Backend::MklLike, threads).gemm(&a, &b);
        println!(
            "threads={threads}: max|Δ| vs single = {:.1e}",
            ref_c.max_abs_diff(&c)
        );
    }
    println!("\npaper Fig 6: MKL ≈ 1.9× OpenBLAS at 32 threads; the repro target is the same ordering single-threaded (see EXPERIMENTS.md §Perf).");
}
