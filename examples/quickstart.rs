//! Quickstart: generate a small synthetic Friends subject, fit the
//! brain-encoding ridge through the `engine::Engine` session API, and
//! print the paper's headline quality numbers (Fig. 4/5-style) — all
//! native, no artifacts needed. Runs in well under a minute.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use fmri_encode::blas::{Backend, Blas};
use fmri_encode::config::{Args, ExperimentConfig};
use fmri_encode::coordinator::Strategy;
use fmri_encode::data::catalog::Resolution;
use fmri_encode::data::friends::generate;
use fmri_encode::encoding::{run_null_encoding, EncodeOpts};
use fmri_encode::engine::{EncodeRequest, Engine, FitRequest};
use fmri_encode::util::{human_secs, Stopwatch};

fn main() -> anyhow::Result<()> {
    // Quick-scale experiment config (same path the CLI uses).
    let args = Args::parse(&["quickstart".into(), "--quick".into()])?;
    let exp = ExperimentConfig::from_args(&args)?;

    println!("== fmri-encode quickstart ==");
    let sw = Stopwatch::start();
    let ds = generate(&exp.friends, 1, Resolution::Parcels);
    println!(
        "synthetic sub-01 parcels dataset: X ({} × {}), Y ({} × {}) in {}",
        ds.n(), ds.p(), ds.n(), ds.t(), human_secs(sw.secs())
    );

    // One long-lived engine serves every request below; requests are
    // builder-style and return Result instead of panicking on bad input.
    let engine = Engine::new();

    // 1. Distributed fit: B-MOR across 4 (simulated) nodes. Cold — the
    //    design is decomposed (inner folds + 1 eigendecompositions) and
    //    the shared plan lands in the engine's cache.
    let req = FitRequest::new(&ds.x, &ds.y).strategy(Strategy::Bmor).nodes(4);
    let fit = engine.fit(&req)?;
    println!(
        "\nB-MOR fit over {} batches in {}: λ* per batch = {:?}",
        fit.batches.len(),
        human_secs(fit.wall_secs),
        fit.best_lambda_per_batch
    );

    // 2. Refit against the SAME design (the serving scenario): the plan
    //    cache makes it warm — zero new eigendecompositions, sweeps only,
    //    bit-identical weights.
    let refit = engine.fit(&req)?;
    assert!(refit.plan_reused, "second fit should hit the plan cache");
    assert_eq!(fit.weights.max_abs_diff(&refit.weights), 0.0);
    println!(
        "warm refit in {} ({} cached plan, 0 eigendecompositions)",
        human_secs(refit.wall_secs),
        engine.cached_plans()
    );

    // 3. Encoding quality + the null control (the paper's Figs. 4–5).
    let real = engine.encode(&EncodeRequest::new(&ds))?;
    let null = run_null_encoding(
        &Blas::new(Backend::MklLike, 1),
        &ds,
        EncodeOpts::default(),
        99,
    );
    println!("\nheld-out Pearson r (visual / other / max):");
    println!(
        "  matched stimuli:  {:.3} / {:.3} / {:.3}",
        real.summary.mean_visual, real.summary.mean_other, real.summary.max_r
    );
    println!(
        "  shuffled (null):  {:.3} / {:.3} / {:.3}",
        null.summary.mean_visual, null.summary.mean_other, null.summary.max_r
    );
    println!(
        "\nencoding beats the null by {:.1}× on visual targets (paper: ~10×)",
        real.summary.mean_visual / null.summary.mean_visual.abs().max(1e-3)
    );
    Ok(())
}
