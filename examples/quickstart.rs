//! Quickstart: generate a small synthetic Friends subject, fit the
//! brain-encoding ridge with the B-MOR coordinator, and print the paper's
//! headline quality numbers (Fig. 4/5-style) — all native, no artifacts
//! needed. Runs in well under a minute.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use fmri_encode::blas::{Backend, Blas};
use fmri_encode::config::{Args, ExperimentConfig};
use fmri_encode::coordinator::{self, DistConfig, Strategy};
use fmri_encode::data::catalog::Resolution;
use fmri_encode::data::friends::generate;
use fmri_encode::encoding::{run_encoding, run_null_encoding, EncodeOpts};
use fmri_encode::util::{human_secs, Stopwatch};

fn main() -> anyhow::Result<()> {
    // Quick-scale experiment config (same path the CLI uses).
    let args = Args::parse(&["quickstart".into(), "--quick".into()])?;
    let exp = ExperimentConfig::from_args(&args)?;

    println!("== fmri-encode quickstart ==");
    let sw = Stopwatch::start();
    let ds = generate(&exp.friends, 1, Resolution::Parcels);
    println!(
        "synthetic sub-01 parcels dataset: X ({} × {}), Y ({} × {}) in {}",
        ds.n(), ds.p(), ds.n(), ds.t(), human_secs(sw.secs())
    );

    // 1. Distributed fit: B-MOR across 4 (simulated) nodes.
    let cfg = DistConfig {
        strategy: Strategy::Bmor,
        nodes: 4,
        threads_per_node: 1,
        backend: Backend::MklLike,
        ..Default::default()
    };
    let fit = coordinator::fit(&ds.x, &ds.y, &cfg);
    println!(
        "\nB-MOR fit over {} batches in {}: λ* per batch = {:?}",
        fit.batches.len(),
        human_secs(fit.wall_secs),
        fit.best_lambda_per_batch
    );

    // 2. Encoding quality + the null control (the paper's Figs. 4–5).
    let blas = Blas::new(Backend::MklLike, 1);
    let real = run_encoding(&blas, &ds, EncodeOpts::default());
    let null = run_null_encoding(&blas, &ds, EncodeOpts::default(), 99);
    println!("\nheld-out Pearson r (visual / other / max):");
    println!(
        "  matched stimuli:  {:.3} / {:.3} / {:.3}",
        real.summary.mean_visual, real.summary.mean_other, real.summary.max_r
    );
    println!(
        "  shuffled (null):  {:.3} / {:.3} / {:.3}",
        null.summary.mean_visual, null.summary.mean_other, null.summary.max_r
    );
    println!(
        "\nencoding beats the null by {:.1}× on visual targets (paper: ~10×)",
        real.summary.mean_visual / null.summary.mean_visual.abs().max(1e-3)
    );
    Ok(())
}
