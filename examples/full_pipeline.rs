//! End-to-end driver: the complete three-layer system on a real (synthetic)
//! workload, proving all layers compose.
//!
//!   procedural movie frames (rust)
//!     → L2/L1 AOT feature extractor via PJRT (`features_main`)
//!     → 4-TR windowing (paper §2.2.2)
//!     → planted HRF brain responses (visual network carries signal)
//!     → B-MOR distributed fit (coordinator, native compute)
//!     → held-out Pearson r map + shuffled-feature null (Figs. 4–5)
//!     → XLA-path fit of the same problem (runtime::XlaRidge) and a
//!       native-vs-XLA λ*/score parity check
//!
//! The run log (stage timings, r statistics, parity deltas) is the source
//! of the EXPERIMENTS.md §E2E numbers.
//!
//! ```bash
//! make artifacts && cargo run --release --example full_pipeline [-- --small]
//! ```

use anyhow::Result;

use fmri_encode::blas::{Backend, Blas};
use fmri_encode::coordinator::Strategy;
use fmri_encode::cv::{kfold, pearson_cols, train_test_split};
use fmri_encode::data::friends::window_features;
use fmri_encode::encoding::RSummary;
use fmri_encode::engine::{Engine, FitRequest};
use fmri_encode::hrf;
use fmri_encode::linalg::Mat;
use fmri_encode::masker::{atlas::Atlas, BrainGrid};
use fmri_encode::ridge;
use fmri_encode::runtime::{literal_to_mat, Runtime, XlaRidge};
use fmri_encode::util::{human_secs, Pcg64, Stopwatch};

/// Procedural "Friends" frames: two Gaussian blobs whose position, size
/// and colour follow slow AR(1) latents — a stand-in for the slow visual
/// statistics of a TV episode. Returns flat f32 NHWC (n, 32, 32, 3).
fn generate_frames(n: usize, rng: &mut Pcg64) -> Vec<f32> {
    let (h, w) = (32usize, 32usize);
    let mut frames = vec![0f32; n * h * w * 3];
    // 8 latents: blob A (x, y, r), blob B (x, y), colours.
    let mut lat = [0f64; 8];
    let mut vel = [0f64; 8];
    for f in 0..n {
        for k in 0..8 {
            vel[k] = 0.9 * vel[k] + 0.1 * rng.normal();
            lat[k] = (lat[k] + 0.15 * vel[k]).clamp(-2.5, 2.5);
        }
        let (ax, ay) = (16.0 + 10.0 * lat[0] / 2.5, 16.0 + 10.0 * lat[1] / 2.5);
        let ar = 3.0 + 1.5 * (lat[2] / 2.5 + 1.0);
        let (bx, by) = (16.0 - 10.0 * lat[3] / 2.5, 16.0 + 10.0 * lat[4] / 2.5);
        let col = [0.5 + 0.2 * lat[5], 0.5 + 0.2 * lat[6], 0.5 + 0.2 * lat[7]];
        let base = f * h * w * 3;
        for y in 0..h {
            for x in 0..w {
                let da = ((x as f64 - ax).powi(2) + (y as f64 - ay).powi(2)) / (2.0 * ar * ar);
                let db = ((x as f64 - bx).powi(2) + (y as f64 - by).powi(2)) / 18.0;
                let ga = (-da).exp();
                let gb = 0.7 * (-db).exp();
                let grad = 0.1 * (x as f64 / w as f64);
                for c in 0..3 {
                    frames[base + (y * w + x) * 3 + c] =
                        (grad + ga * col[c] + gb * (1.0 - col[c])).clamp(0.0, 1.0) as f32;
                }
            }
        }
    }
    frames
}

/// Push frames through the AOT feature extractor in fixed batches.
fn extract_features(rt: &Runtime, preset: &str, frames: &[f32], n: usize) -> Result<Mat> {
    let cfg = *rt.manifest.preset(preset).unwrap();
    let (fb, fd) = (cfg.feat_batch, cfg.feat_dim);
    let frame_len = 32 * 32 * 3;
    let mut out = Mat::zeros(n, fd);
    let mut batch = vec![0f32; fb * frame_len];
    let mut i = 0;
    while i < n {
        let take = (n - i).min(fb);
        batch[..take * frame_len]
            .copy_from_slice(&frames[i * frame_len..(i + take) * frame_len]);
        for v in batch[take * frame_len..].iter_mut() {
            *v = 0.0;
        }
        let lit = xla::Literal::vec1(&batch).reshape(&[fb as i64, 32, 32, 3])?;
        let res = rt.run(&format!("features_{preset}"), &[lit])?;
        let feats = res[0].to_vec::<f32>()?;
        for r in 0..take {
            for c in 0..fd {
                out.set(i + r, c, feats[r * fd + c] as f64);
            }
        }
        i += take;
    }
    Ok(out)
}

fn main() -> Result<()> {
    let small = std::env::args().any(|a| a == "--small");
    let preset = if small { "small" } else { "main" };
    let total = Stopwatch::start();
    println!("== full_pipeline (preset: {preset}) ==");

    let rt = Runtime::open("artifacts")?;
    let xr = XlaRidge::new(&rt, preset)?;
    let pcfg = xr.cfg;
    let window = 4;
    assert_eq!(pcfg.feat_dim * window, pcfg.p, "preset feature chain mismatch");

    // Problem size: n time samples, t brain targets (multiples of the
    // artifact chunk sizes keep the XLA path exact).
    let n = if small { 512 } else { 1536 };
    let t = if small { 256 } else { 2048 };
    let mut rng = Pcg64::seeded(2020);

    // -- stage 1: stimulus frames -----------------------------------------
    let sw = Stopwatch::start();
    let frames = generate_frames(n, &mut rng);
    println!("[1] frames: {n} × 32×32×3 in {}", human_secs(sw.secs()));

    // -- stage 2: features via the AOT CNN (L2/L1 through PJRT) -----------
    let sw = Stopwatch::start();
    let mut feats = extract_features(&rt, preset, &frames, n)?;
    feats.zscore_cols();
    println!(
        "[2] XLA features: ({} × {}) in {} (platform {})",
        feats.rows(), feats.cols(), human_secs(sw.secs()), rt.platform()
    );

    // -- stage 3: windowing + synthetic brain ------------------------------
    let sw = Stopwatch::start();
    let mut x = window_features(&feats, window);
    x.zscore_cols();
    // Brain: MIST-like atlas; visual voxels carry HRF-convolved signal.
    let grid = BrainGrid::synthetic((24, 28, 22), 1);
    let atlas = Atlas::mist_like(&grid, 444, 7, 2020);
    let visual = atlas.visual_roi();
    let blas = Blas::new(Backend::MklLike, 1);
    let w_true = Mat::randn(feats.cols(), t, &mut rng);
    let neural = blas.gemm(&feats, &w_true);
    let mut bold = hrf::convolve_cols(&neural, &hrf::canonical(hrf::TR_SECS));
    bold.zscore_cols();
    let mut y = Mat::zeros(n, t);
    let mut is_visual = vec![false; t];
    for j in 0..t {
        let vis = visual[j % visual.len()];
        is_visual[j] = vis;
        let frac: f64 = if vis { 0.5 } else { 0.01 };
        let (sig, noise) = (frac.sqrt(), (1.0 - frac).sqrt());
        for i in 0..n {
            y.set(i, j, sig * bold.get(i, j) + noise * rng.normal());
        }
    }
    y.zscore_cols();
    println!(
        "[3] brain targets: ({} × {}), {} visual, in {}",
        n, t,
        is_visual.iter().filter(|&&v| v).count(),
        human_secs(sw.secs())
    );

    // -- stage 4: B-MOR distributed fit (native compute) ------------------
    let outer = train_test_split(n, 0.125, 0);
    let xtr = x.rows_gather(&outer.train);
    let ytr = y.rows_gather(&outer.train);
    let xte = x.rows_gather(&outer.val);
    let yte = y.rows_gather(&outer.val);
    // Session engine: every fit below goes through one typed entry
    // point; bad requests surface as EngineError instead of panics.
    fn bmor_request<'a>(x: &'a Mat, y: &'a Mat) -> FitRequest<'a> {
        FitRequest::new(x, y)
            .strategy(Strategy::Bmor)
            .nodes(4)
            .threads_per_node(1)
            .backend(Backend::MklLike)
            .folds(2)
            .seed(0)
    }
    let engine = Engine::new();
    let sw = Stopwatch::start();
    let fit = engine.fit(&bmor_request(&xtr, &ytr))?;
    println!(
        "[4] B-MOR fit: {} batches in {} (gram {} | eigh {} | sweep {} | solve {})",
        fit.batches.len(),
        human_secs(sw.secs()),
        human_secs(fit.timings.gram_secs),
        human_secs(fit.timings.eigh_secs),
        human_secs(fit.timings.sweep_secs),
        human_secs(fit.timings.solve_secs),
    );
    println!("    λ* per batch: {:?}", fit.best_lambda_per_batch);

    // -- stage 5: held-out quality + null (Figs. 4–5) ----------------------
    let sw = Stopwatch::start();
    let pred = ridge::predict(&blas, &xte, &fit.weights);
    let rs = pearson_cols(&pred, &yte);
    let summary = RSummary::from_rs(&rs, &is_visual);
    // Null: break the stimulus↔brain pairing.
    let perm = Pcg64::seeded(7).permutation(xtr.rows());
    let x_null = xtr.rows_gather(&perm);
    let fit_null = engine.fit(&bmor_request(&x_null, &ytr))?;
    let pred_null = ridge::predict(&blas, &xte, &fit_null.weights);
    let null = RSummary::from_rs(&pearson_cols(&pred_null, &yte), &is_visual);
    println!(
        "[5] quality in {}: visual r {:.3} (q95 {:.3}, max {:.3}) | other {:.3} | null visual {:.3}",
        human_secs(sw.secs()),
        summary.mean_visual, summary.q95_visual, summary.max_r,
        summary.mean_other, null.mean_visual
    );

    // -- stage 6: XLA-path fit + parity ------------------------------------
    let sw = Stopwatch::start();
    let mut splits = kfold(xtr.rows(), 2, Some(0));
    for s in &mut splits {
        s.val.truncate(pcfg.nv);
    }
    let xfit = xr.fit_cv(&xtr, &ytr, &splits)?;
    let blas1 = Blas::new(Backend::MklLike, 1);
    let nfit = ridge::fit_ridge_cv(&blas1, &xtr, &ytr, &xr.lambdas, &splits);
    let wdiff = xfit.weights.max_abs_diff(&nfit.weights);
    println!(
        "[6] XLA fit in {}: λ* = {} (native λ* = {}), weight max|Δ| = {:.2e}",
        human_secs(sw.secs()),
        xfit.best_lambda,
        nfit.best_lambda,
        wdiff
    );
    let _ = literal_to_mat; // (api surface used by other drivers)

    // -- verdict ------------------------------------------------------------
    let ok = summary.mean_visual > 0.25
        && summary.mean_visual > 5.0 * null.mean_visual.abs().max(1e-3)
        && xfit.best_idx == nfit.best_idx
        && wdiff < 1e-6;
    println!(
        "\n== e2e {} in {} — visual r {:.3}, null {:.3}, XLA/native parity {:.1e} ==",
        if ok { "PASS" } else { "FAIL" },
        human_secs(total.secs()),
        summary.mean_visual,
        null.mean_visual,
        wdiff
    );
    if !ok {
        std::process::exit(1);
    }
    Ok(())
}
