//! Fig. 5 driver: encoding accuracy against a shuffled-feature null, with
//! several permutation seeds to show the null's spread.
//!
//! ```bash
//! cargo run --release --example null_distribution
//! ```

use fmri_encode::blas::{Backend, Blas};
use fmri_encode::config::{Args, ExperimentConfig};
use fmri_encode::data::catalog::Resolution;
use fmri_encode::data::friends::generate;
use fmri_encode::encoding::{run_encoding, run_null_encoding, EncodeOpts};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&["null".into(), "--quick".into()])?;
    let exp = ExperimentConfig::from_args(&args)?;
    let blas = Blas::new(Backend::MklLike, 1);
    let ds = generate(&exp.friends, 1, Resolution::Parcels);

    println!("== Fig 5 reproduction: matched vs shuffled encoding (sub-01) ==");
    let real = run_encoding(&blas, &ds, EncodeOpts::default());
    println!(
        "matched   : visual mean r = {:+.4}, q95 = {:+.4}, max = {:+.4}",
        real.summary.mean_visual, real.summary.q95_visual, real.summary.max_r
    );

    let mut null_means = Vec::new();
    for seed in 0..5u64 {
        let null = run_null_encoding(&blas, &ds, EncodeOpts::default(), 1000 + seed);
        println!(
            "shuffled#{seed}: visual mean r = {:+.4}, q95 = {:+.4}, max = {:+.4}",
            null.summary.mean_visual, null.summary.q95_visual, null.summary.max_r
        );
        null_means.push(null.summary.mean_visual);
    }
    let null_mean = null_means.iter().sum::<f64>() / null_means.len() as f64;
    let null_max = null_means.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    println!(
        "\nnull distribution of visual-mean r: mean {:+.4}, max {:+.4}",
        null_mean, null_max
    );
    println!(
        "matched / |null| ratio = {:.1}× (paper: matched ≈ 0.5, null < 0.05 — ~an order of magnitude)",
        real.summary.mean_visual / null_mean.abs().max(1e-3)
    );
    anyhow::ensure!(
        real.summary.mean_visual > 4.0 * null_max.abs().max(1e-3),
        "encoding does not separate from the null"
    );
    Ok(())
}
