//! Fig. 5 driver: encoding accuracy against a shuffled-feature null, with
//! several permutation seeds to show the null's spread.
//!
//! ```bash
//! cargo run --release --example null_distribution
//! ```

use fmri_encode::config::{Args, ExperimentConfig};
use fmri_encode::data::catalog::Resolution;
use fmri_encode::data::friends::generate;
use fmri_encode::engine::{EncodeRequest, Engine};
use fmri_encode::util::Pcg64;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&["null".into(), "--quick".into()])?;
    let exp = ExperimentConfig::from_args(&args)?;
    let ds = generate(&exp.friends, 1, Resolution::Parcels);

    // One session engine for the matched run and every permutation null.
    let engine = Engine::new();
    println!("== Fig 5 reproduction: matched vs shuffled encoding (sub-01) ==");
    let real = engine.encode(&EncodeRequest::new(&ds))?;
    println!(
        "matched   : visual mean r = {:+.4}, q95 = {:+.4}, max = {:+.4}",
        real.summary.mean_visual, real.summary.q95_visual, real.summary.max_r
    );

    let mut null_means = Vec::new();
    for seed in 0..5u64 {
        // Break the stimulus↔brain pairing, then run the identical
        // pipeline through the same engine.
        let mut shuffled = ds.clone();
        shuffled.x = ds.x.rows_gather(&Pcg64::seeded(1000 + seed).permutation(ds.n()));
        let null = engine.encode(&EncodeRequest::new(&shuffled))?;
        // Each permutation is a fresh design that will never repeat —
        // drop its plan instead of accumulating one cache entry (plus a
        // resident copy of the shuffled X) per null.
        engine.clear_plan_cache();
        println!(
            "shuffled#{seed}: visual mean r = {:+.4}, q95 = {:+.4}, max = {:+.4}",
            null.summary.mean_visual, null.summary.q95_visual, null.summary.max_r
        );
        null_means.push(null.summary.mean_visual);
    }
    let null_mean = null_means.iter().sum::<f64>() / null_means.len() as f64;
    let null_max = null_means.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    println!(
        "\nnull distribution of visual-mean r: mean {:+.4}, max {:+.4}",
        null_mean, null_max
    );
    println!(
        "matched / |null| ratio = {:.1}× (paper: matched ≈ 0.5, null < 0.05 — ~an order of magnitude)",
        real.summary.mean_visual / null_mean.abs().max(1e-3)
    );
    anyhow::ensure!(
        real.summary.mean_visual > 4.0 * null_max.abs().max(1e-3),
        "encoding does not separate from the null"
    );
    Ok(())
}
