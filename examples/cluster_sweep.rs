//! Cluster sweep: the distributed-scaling story (Figs. 8–10) on the
//! discrete-event simulator, with this machine's measured kernel
//! throughput as the cost model.
//!
//! ```bash
//! cargo run --release --example cluster_sweep
//! ```

use fmri_encode::cluster::ClusterSpec;
use fmri_encode::coordinator::Strategy;
use fmri_encode::engine::{Engine, SimRequest};
use fmri_encode::perfmodel::{calibrate, FitShape};
use fmri_encode::ridge::LAMBDA_GRID;
use fmri_encode::util::human_secs;

fn main() {
    println!("== cluster sweep: MOR vs B-MOR vs single-node RidgeCV ==");
    let cal = calibrate(true);
    println!(
        "calibration: mkl-like {:.2} GF/s, openblas-like {:.2} GF/s, eigh {:.2} GF/s\n",
        cal.gemm_flops_mkl / 1e9,
        cal.gemm_flops_openblas / 1e9,
        cal.eigh_flops / 1e9
    );
    // Session engine: this machine's measured calibration prices every
    // request below.
    let engine = Engine::with_calibration(cal, ClusterSpec::default());

    // Whole-brain (B-MOR) truncation shape at repro scale.
    let shape = FitShape { n: 2048, p: 512, t: 32_000, r: LAMBDA_GRID.len(), splits: 3 };
    println!(
        "problem: n={} p={} t={} r={} splits={}\n",
        shape.n, shape.p, shape.t, shape.r, shape.splits
    );

    let sim = |strategy, nodes, threads| {
        engine
            .simulate(
                &SimRequest::new(shape)
                    .strategy(strategy)
                    .nodes(nodes)
                    .threads_per_node(threads),
            )
            .expect("valid simulation request")
            .makespan
    };
    let single1 = sim(Strategy::Single, 1, 1);
    println!("single-node RidgeCV, 1 thread:  {:>10}", human_secs(single1));
    let single32 = sim(Strategy::Single, 1, 32);
    println!("single-node RidgeCV, 32 threads:{:>10}\n", human_secs(single32));

    println!("{:>6} {:>8} | {:>12} {:>8} | {:>12} {:>8}", "nodes", "threads", "B-MOR", "DSU", "MOR", "vs 1×32");
    for nodes in [1, 2, 4, 8] {
        for threads in [1, 8, 32] {
            let bmor = sim(Strategy::Bmor, nodes, threads);
            let mor = sim(Strategy::Mor, nodes, threads);
            println!(
                "{:>6} {:>8} | {:>12} {:>7.1}× | {:>12} {:>7.0}×",
                nodes,
                threads,
                human_secs(bmor),
                single1 / bmor,
                human_secs(mor),
                mor / single32
            );
        }
    }
    println!("\npaper: B-MOR up to ~33× DSU at 8 nodes × 32 threads; MOR ~1000× slower than 1-node/32-thread RidgeCV");
}
