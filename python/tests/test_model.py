"""L2 model graph: staged pipeline vs closed-form ridge, fused-fit parity,
feature extractor determinism, λ-selection behaviour."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model

SETTINGS = dict(max_examples=10, deadline=None)


def _data(n, p, t, nv, seed, noise=0.1):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((p, t))
    xtr = rng.standard_normal((n, p))
    ytr = xtr @ w + noise * rng.standard_normal((n, t))
    xval = rng.standard_normal((nv, p))
    yval = xval @ w + noise * rng.standard_normal((nv, t))
    return map(jnp.asarray, (xtr, ytr, xval, yval))


def _staged_fit(xtr, ytr, xval, yval, lams, pallas=True):
    """Run the exact staged sequence the rust coordinator drives."""
    k, c = model.gram_fn(xtr, ytr, pallas=pallas)
    e, v = model.eigh_fn(k)
    z, a = model.prep_fn(v, c, xval, pallas=pallas)
    scores = model.sweep_fn(a, e, z, yval, lams, pallas=pallas)
    best = int(np.argmax(np.asarray(scores).mean(axis=1)))
    w = model.solve_fn(v, e, z, lams[best], pallas=pallas)
    return scores, best, w


class TestRidgePath:
    @settings(**SETTINGS)
    @given(p=st.integers(4, 24), t=st.integers(2, 10), seed=st.integers(0, 999))
    def test_solve_matches_closed_form(self, p, t, seed):
        n = 4 * p
        xtr, ytr, _, _ = _data(n, p, t, 8, seed)
        lam = 37.5
        k, c = model.gram_fn(xtr, ytr)
        e, v = model.eigh_fn(k)
        z = jnp.asarray(np.asarray(v).T @ np.asarray(c))
        w = model.solve_fn(v, e, z, jnp.asarray(lam))
        want = model.ridge_closed_form_ref(xtr, ytr, lam)
        np.testing.assert_allclose(np.asarray(w), np.asarray(want),
                                   rtol=1e-6, atol=1e-7)

    def test_lambda_zero_is_ols(self):
        xtr, ytr, _, _ = _data(80, 10, 4, 8, 0, noise=0.0)
        k, c = model.gram_fn(xtr, ytr)
        e, v = model.eigh_fn(k)
        z, _ = model.prep_fn(v, c, xtr)
        w = model.solve_fn(v, e, z, jnp.asarray(1e-10))
        # Noise-free targets: OLS recovers the planted weights exactly.
        resid = np.asarray(xtr @ w - ytr)
        assert np.abs(resid).max() < 1e-6

    def test_lambda_infinity_shrinks_to_zero(self):
        xtr, ytr, _, _ = _data(60, 8, 3, 8, 1)
        k, c = model.gram_fn(xtr, ytr)
        e, v = model.eigh_fn(k)
        z, _ = model.prep_fn(v, c, xtr)
        w = model.solve_fn(v, e, z, jnp.asarray(1e12))
        assert np.abs(np.asarray(w)).max() < 1e-6

    def test_staged_selects_sane_lambda(self):
        """Low-noise planted data ⇒ CV prefers the small-λ end of the grid."""
        lams = jnp.asarray(model.LAMBDA_GRID)
        xtr, ytr, xval, yval = _data(200, 16, 8, 64, 2, noise=0.05)
        scores, best, w = _staged_fit(xtr, ytr, xval, yval, lams)
        assert best <= 2
        assert np.asarray(scores)[best].mean() > 0.95

    def test_pallas_and_ref_paths_agree(self):
        lams = jnp.asarray(model.LAMBDA_GRID)
        xtr, ytr, xval, yval = _data(120, 12, 6, 40, 3)
        s1, b1, w1 = _staged_fit(xtr, ytr, xval, yval, lams, pallas=True)
        s2, b2, w2 = _staged_fit(xtr, ytr, xval, yval, lams, pallas=False)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=1e-7, atol=1e-8)
        assert b1 == b2
        np.testing.assert_allclose(np.asarray(w1), np.asarray(w2),
                                   rtol=1e-7, atol=1e-8)

    def test_fused_fit_matches_staged(self):
        lams = jnp.asarray(model.LAMBDA_GRID)
        xtr, ytr, xval, yval = _data(100, 10, 5, 30, 4)
        s1, b1, w1 = model.fit_fused_fn(xtr, ytr, xval, yval, lams)
        s2, b2, w2 = _staged_fit(xtr, ytr, xval, yval, lams)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=1e-7, atol=1e-8)
        assert int(b1) == b2
        np.testing.assert_allclose(np.asarray(w1), np.asarray(w2),
                                   rtol=1e-7, atol=1e-8)


class TestFeatures:
    def test_deterministic(self):
        rng = np.random.default_rng(5)
        frames = jnp.asarray(rng.uniform(0, 1, (4, 32, 32, 3)), jnp.float32)
        f1 = np.asarray(model.features_fn(frames))
        f2 = np.asarray(model.features_fn(frames))
        np.testing.assert_array_equal(f1, f2)

    def test_shape_and_bounds(self):
        rng = np.random.default_rng(6)
        frames = jnp.asarray(rng.uniform(0, 1, (8, 32, 32, 3)), jnp.float32)
        f = np.asarray(model.features_fn(frames, feat_dim=64))
        assert f.shape == (8, 64)
        assert (np.abs(f) <= 1.0).all()          # tanh-bounded

    def test_distinct_frames_distinct_features(self):
        rng = np.random.default_rng(7)
        frames = jnp.asarray(rng.uniform(0, 1, (2, 32, 32, 3)), jnp.float32)
        f = np.asarray(model.features_fn(frames))
        assert np.abs(f[0] - f[1]).max() > 1e-4
