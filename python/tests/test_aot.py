"""AOT emission invariants: every artifact must be loadable by the rust
PJRT client (no custom-calls), manifest must be consistent, and the HLO
round-trip must preserve numerics (executed via jax's own CPU client)."""
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.aot import PRESETS, entries_for, to_hlo_text

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestEmission:
    def test_no_custom_call_small(self):
        """The loadability invariant, per artifact of the small preset."""
        for name, fn, args in entries_for("small", PRESETS["small"], True):
            text = to_hlo_text(jax.jit(fn).lower(*args))
            assert "custom-call" not in text, f"{name} has a custom-call"

    def test_eigh_has_no_custom_call_but_lapack_would(self):
        """Sanity of the invariant itself: jnp.linalg.eigh DOES emit a
        custom call on CPU, our jacobi path does not."""
        k = jax.ShapeDtypeStruct((16, 16), jnp.float64)
        lap = to_hlo_text(jax.jit(jnp.linalg.eigh).lower(k))
        assert "custom-call" in lap
        ours = to_hlo_text(jax.jit(lambda m: model.eigh_fn(m)).lower(k))
        assert "custom-call" not in ours

    def test_hlo_text_roundtrip_numerics(self):
        """Lower → HLO text → recompile (fresh client) → same numbers."""
        def fn(a, b):
            return (model.predict_fn(a, b),)

        spec = jax.ShapeDtypeStruct((8, 8), jnp.float64)
        text = to_hlo_text(jax.jit(fn).lower(spec, spec))
        # The text must at least parse back to an HLO module in this
        # process; the authoritative executable round-trip (text → PJRT
        # compile → execute → compare) runs in rust/tests/runtime_parity.rs
        # against the very client that serves the hot path.
        try:
            mod = xc._xla.hlo_module_from_text(text)
        except AttributeError:
            pytest.skip("this jaxlib exposes no hlo_module_from_text")
        assert "f64[8,8]" in mod.to_string()


class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        path = os.path.join(ART, "manifest.json")
        if not os.path.exists(path):
            pytest.skip("run `make artifacts` first")
        with open(path) as f:
            return json.load(f)

    def test_entries_exist_on_disk(self, manifest):
        for ent in manifest["entries"]:
            assert os.path.exists(os.path.join(ART, ent["file"])), ent["name"]

    def test_lambda_grid_matches_paper(self, manifest):
        assert manifest["lambda_grid"] == [
            0.1, 1, 100, 200, 300, 400, 600, 800, 900, 1000, 1200]

    def test_shapes_consistent_with_presets(self, manifest):
        for ent in manifest["entries"]:
            cfg = manifest["presets"][ent["preset"]]
            if ent["name"].startswith("gram"):
                assert ent["inputs"][0]["shape"] == [cfg["n_chunk"], cfg["p"]]
                assert ent["outputs"][0]["shape"] == [cfg["p"], cfg["p"]]
            if ent["name"].startswith("sweep"):
                assert ent["outputs"][0]["shape"] == [cfg["r"], cfg["t_chunk"]]

    def test_artifact_files_have_no_custom_call(self, manifest):
        for ent in manifest["entries"]:
            with open(os.path.join(ART, ent["file"])) as f:
                assert "custom-call" not in f.read(), ent["name"]
