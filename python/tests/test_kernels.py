"""L1 Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (including non-tile-multiple edges) and dtypes;
every kernel must match its `ref.py` oracle to tight tolerances. This is
the core correctness signal for the compute hot-spot.
"""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gemm import matmul
from compile.kernels.gram import gram_chunk, gram_chunk_fused, syrk
from compile.kernels.pearson import pearson
from compile.kernels.ridge_sweep import lambda_sweep, ridge_weights

DIM = st.integers(min_value=1, max_value=90)
SMALL = st.integers(min_value=1, max_value=40)
DTYPES = st.sampled_from([np.float32, np.float64])
SETTINGS = dict(max_examples=25, deadline=None)


def _rng(seed):
    return np.random.default_rng(seed)


def _tol(dt):
    return dict(rtol=2e-4, atol=2e-4) if dt == np.float32 else dict(rtol=1e-9, atol=1e-9)


class TestMatmul:
    @settings(**SETTINGS)
    @given(m=DIM, k=DIM, n=DIM, dt=DTYPES, seed=st.integers(0, 2**16))
    def test_matches_ref(self, m, k, n, dt, seed):
        r = _rng(seed)
        a = jnp.asarray(r.standard_normal((m, k)), dt)
        b = jnp.asarray(r.standard_normal((k, n)), dt)
        np.testing.assert_allclose(matmul(a, b), ref.matmul_ref(a, b), **_tol(dt))

    def test_tile_multiple_shapes(self):
        r = _rng(0)
        a = jnp.asarray(r.standard_normal((256, 128)))
        b = jnp.asarray(r.standard_normal((128, 256)))
        np.testing.assert_allclose(matmul(a, b), np.asarray(a) @ np.asarray(b),
                                   rtol=1e-10)

    def test_single_element(self):
        a = jnp.asarray([[2.0]])
        b = jnp.asarray([[3.0]])
        np.testing.assert_allclose(matmul(a, b), [[6.0]])

    def test_zero_matrix(self):
        a = jnp.zeros((10, 20))
        b = jnp.asarray(_rng(1).standard_normal((20, 5)))
        np.testing.assert_allclose(matmul(a, b), np.zeros((10, 5)))

    def test_identity(self):
        i = jnp.eye(33)
        b = jnp.asarray(_rng(2).standard_normal((33, 17)))
        np.testing.assert_allclose(matmul(i, b), b, rtol=1e-12)


class TestGram:
    @settings(**SETTINGS)
    @given(n=DIM, p=SMALL, t=SMALL, dt=DTYPES, seed=st.integers(0, 2**16))
    def test_gram_chunk(self, n, p, t, dt, seed):
        r = _rng(seed)
        x = jnp.asarray(r.standard_normal((n, p)), dt)
        y = jnp.asarray(r.standard_normal((n, t)), dt)
        k, c = gram_chunk(x, y)
        k2, c2 = ref.gram_ref(x, y)
        np.testing.assert_allclose(k, k2, **_tol(dt))
        np.testing.assert_allclose(c, c2, **_tol(dt))

    @settings(**SETTINGS)
    @given(n=DIM, p=SMALL, t=SMALL, seed=st.integers(0, 2**16))
    def test_gram_fused(self, n, p, t, seed):
        r = _rng(seed)
        x = jnp.asarray(r.standard_normal((n, p)))
        y = jnp.asarray(r.standard_normal((n, t)))
        k, c = gram_chunk_fused(x, y)
        k2, c2 = ref.gram_ref(x, y)
        np.testing.assert_allclose(k, k2, rtol=1e-9, atol=1e-9)
        np.testing.assert_allclose(c, c2, rtol=1e-9, atol=1e-9)

    def test_syrk_symmetry(self):
        x = jnp.asarray(_rng(3).standard_normal((100, 64)))
        k = np.asarray(syrk(x))
        np.testing.assert_allclose(k, k.T, rtol=1e-12)

    def test_gram_psd(self):
        """XᵀX must be positive semi-definite."""
        x = jnp.asarray(_rng(4).standard_normal((50, 30)))
        k, _ = gram_chunk(x, jnp.zeros((50, 1)))
        ev = np.linalg.eigvalsh(np.asarray(k))
        assert ev.min() > -1e-9

    def test_streaming_accumulation(self):
        """Sum of chunk grams equals full gram (the rust streaming path)."""
        r = _rng(5)
        x = jnp.asarray(r.standard_normal((96, 24)))
        y = jnp.asarray(r.standard_normal((96, 10)))
        k_full, c_full = ref.gram_ref(x, y)
        k_acc = np.zeros_like(k_full)
        c_acc = np.zeros_like(c_full)
        for i in range(0, 96, 32):
            k, c = gram_chunk(x[i:i + 32], y[i:i + 32])
            k_acc += np.asarray(k)
            c_acc += np.asarray(c)
        np.testing.assert_allclose(k_acc, k_full, rtol=1e-9)
        np.testing.assert_allclose(c_acc, c_full, rtol=1e-9)


class TestLambdaSweep:
    @settings(**SETTINGS)
    @given(m=SMALL, p=SMALL, t=SMALL, r=st.integers(1, 11), dt=DTYPES,
           seed=st.integers(0, 2**16))
    def test_matches_ref(self, m, p, t, r, dt, seed):
        rng = _rng(seed)
        a = jnp.asarray(rng.standard_normal((m, p)), dt)
        e = jnp.asarray(np.abs(rng.standard_normal(p)) + 0.5, dt)
        z = jnp.asarray(rng.standard_normal((p, t)), dt)
        lams = jnp.asarray(np.sort(rng.uniform(0.1, 1000, r)), dt)
        out = lambda_sweep(a, e, z, lams)
        want = ref.lambda_sweep_ref(a, e, z, lams)
        np.testing.assert_allclose(out, want, **_tol(dt))

    def test_lambda_monotone_shrinkage(self):
        """Larger λ ⇒ smaller weight norm (ridge's defining property)."""
        rng = _rng(7)
        p, t = 24, 12
        v, _ = np.linalg.qr(rng.standard_normal((p, p)))
        e = jnp.asarray(np.abs(rng.standard_normal(p)) + 0.5)
        z = jnp.asarray(rng.standard_normal((p, t)))
        lams = jnp.asarray([0.1, 1.0, 10.0, 100.0, 1000.0])
        ws = lambda_sweep(jnp.asarray(v), e, z, lams)
        norms = [float(np.linalg.norm(np.asarray(ws[i]))) for i in range(5)]
        assert all(a > b for a, b in zip(norms, norms[1:]))

    def test_single_lambda_equals_ridge_weights(self):
        rng = _rng(8)
        p, t = 16, 8
        v = jnp.asarray(rng.standard_normal((p, p)))
        e = jnp.asarray(np.abs(rng.standard_normal(p)) + 0.5)
        z = jnp.asarray(rng.standard_normal((p, t)))
        w = ridge_weights(v, e, z, jnp.asarray(3.0))
        want = ref.ridge_weights_ref(v, e, z, 3.0)
        np.testing.assert_allclose(w, want, rtol=1e-9)


class TestPearson:
    @settings(**SETTINGS)
    @given(n=st.integers(3, 90), t=DIM, dt=DTYPES, seed=st.integers(0, 2**16))
    def test_matches_ref(self, n, t, dt, seed):
        rng = _rng(seed)
        yh = jnp.asarray(rng.standard_normal((n, t)), dt)
        y = jnp.asarray(rng.standard_normal((n, t)), dt)
        tol = dict(rtol=5e-3, atol=5e-3) if dt == np.float32 else dict(rtol=1e-7, atol=1e-8)
        np.testing.assert_allclose(pearson(yh, y), ref.pearson_ref(yh, y), **tol)

    def test_perfect_correlation(self):
        y = jnp.asarray(_rng(9).standard_normal((50, 7)))
        r = np.asarray(pearson(y, y))
        np.testing.assert_allclose(r, np.ones(7), rtol=1e-6)

    def test_anticorrelation(self):
        y = jnp.asarray(_rng(10).standard_normal((50, 7)))
        r = np.asarray(pearson(-y, y))
        np.testing.assert_allclose(r, -np.ones(7), rtol=1e-6)

    def test_scale_shift_invariance(self):
        rng = _rng(11)
        y = jnp.asarray(rng.standard_normal((64, 9)))
        yh = jnp.asarray(rng.standard_normal((64, 9)))
        r1 = np.asarray(pearson(yh, y))
        r2 = np.asarray(pearson(3.5 * yh + 2.0, y))
        np.testing.assert_allclose(r1, r2, rtol=1e-8, atol=1e-10)

    def test_matches_numpy_corrcoef(self):
        rng = _rng(12)
        yh = rng.standard_normal((40, 5))
        y = rng.standard_normal((40, 5))
        want = np.array([np.corrcoef(yh[:, i], y[:, i])[0, 1] for i in range(5)])
        got = np.asarray(pearson(jnp.asarray(yh), jnp.asarray(y)))
        np.testing.assert_allclose(got, want, rtol=1e-8)
