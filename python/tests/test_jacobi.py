"""Property tests for the pure-HLO Jacobi eigensolver (L2 substrate)."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.jacobi import jacobi_eigh, offdiag_norm, round_robin_schedule

SETTINGS = dict(max_examples=15, deadline=None)


def _spd(p, seed, cond=None):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((2 * p, p))
    k = x.T @ x
    if cond is not None:
        # Rescale spectrum to a target condition number.
        e, v = np.linalg.eigh(k)
        e = np.geomspace(1.0, cond, p)
        k = (v * e) @ v.T
    return k


class TestSchedule:
    @settings(**SETTINGS)
    @given(half=st.integers(1, 24))
    def test_every_pair_once(self, half):
        p = 2 * half
        sched = round_robin_schedule(p)
        assert sched.shape == (p - 1, 2, p // 2)
        seen = set()
        for rnd in sched:
            lo, hi = rnd
            # Disjointness within a round.
            flat = list(lo) + list(hi)
            assert len(set(flat)) == p
            for a, b in zip(lo, hi):
                assert a < b
                seen.add((int(a), int(b)))
        assert len(seen) == p * (p - 1) // 2


class TestEigh:
    @settings(**SETTINGS)
    @given(p=st.integers(2, 48), seed=st.integers(0, 2**16))
    def test_reconstruction(self, p, seed):
        k = _spd(p, seed)
        e, v = jacobi_eigh(jnp.asarray(k))
        e, v = np.asarray(e), np.asarray(v)
        np.testing.assert_allclose((v * e) @ v.T, k, rtol=1e-8, atol=1e-8)

    @settings(**SETTINGS)
    @given(p=st.integers(2, 48), seed=st.integers(0, 2**16))
    def test_matches_lapack(self, p, seed):
        k = _spd(p, seed)
        e, _ = jacobi_eigh(jnp.asarray(k))
        want = np.linalg.eigvalsh(k)
        np.testing.assert_allclose(np.asarray(e), want, rtol=1e-8, atol=1e-8)

    @settings(**SETTINGS)
    @given(p=st.integers(2, 32), seed=st.integers(0, 2**16))
    def test_orthonormal_eigenvectors(self, p, seed):
        k = _spd(p, seed)
        _, v = jacobi_eigh(jnp.asarray(k))
        v = np.asarray(v)
        np.testing.assert_allclose(v.T @ v, np.eye(p), rtol=0, atol=1e-9)

    def test_odd_dimension_padding(self):
        k = _spd(33, 3)
        e, v = jacobi_eigh(jnp.asarray(k))
        assert e.shape == (33,) and v.shape == (33, 33)
        np.testing.assert_allclose(
            (np.asarray(v) * np.asarray(e)) @ np.asarray(v).T, k,
            rtol=1e-8, atol=1e-8)

    def test_ascending_order(self):
        e, _ = jacobi_eigh(jnp.asarray(_spd(20, 4)))
        e = np.asarray(e)
        assert (np.diff(e) >= -1e-12).all()

    def test_diagonal_matrix(self):
        d = np.diag([5.0, 1.0, 3.0, 2.0])
        e, v = jacobi_eigh(jnp.asarray(d))
        np.testing.assert_allclose(np.asarray(e), [1, 2, 3, 5], atol=1e-12)

    def test_ill_conditioned(self):
        """cond=1e8 — the regime ridge regularization exists for."""
        k = _spd(24, 5, cond=1e8)
        e, v = jacobi_eigh(jnp.asarray(k))
        np.testing.assert_allclose(
            (np.asarray(v) * np.asarray(e)) @ np.asarray(v).T, k,
            rtol=1e-6, atol=1e-4)

    def test_convergence_offdiag(self):
        """Off-diagonal mass after the sweeps is at roundoff level."""
        k = _spd(32, 6)
        e, v = jacobi_eigh(jnp.asarray(k))
        # Reconstruct in eigenbasis: Vᵀ K V should be diagonal.
        a = np.asarray(v).T @ k @ np.asarray(v)
        off = float(offdiag_norm(jnp.asarray(a)))
        assert off < 1e-8 * np.linalg.norm(k)
