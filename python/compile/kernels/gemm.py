"""L1 Pallas kernel: tiled GEMM.

The workhorse of the encoding pipeline — every matrix product in the ridge
path (``XᵀX``, ``XᵀY``, ``X_val V``, ``X_test W``) is an instance of this
kernel. The tiling is written for TPU even though this image executes it
with ``interpret=True`` on CPU:

* the grid is (M/bm, N/bn, K/bk) with the K axis innermost, so each (i, j)
  output tile stays resident in VMEM while A/B panels stream through —
  the HBM↔VMEM schedule a CUDA version would express with threadblocks;
* default tiles are 128×128 (MXU-native) with fp32/f64 accumulation in the
  output ref;
* inputs whose dims are not tile multiples are zero-padded by the wrapper
  (zero rows/cols do not perturb a matmul) and the result is sliced back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pad2(x: jnp.ndarray, m: int, n: int) -> jnp.ndarray:
    """Zero-pad a 2-D array up to shape (m, n)."""
    pm, pn = m - x.shape[0], n - x.shape[1]
    if pm == 0 and pn == 0:
        return x
    return jnp.pad(x, ((0, pm), (0, pn)))


def _ceil_to(x: int, b: int) -> int:
    return (x + b - 1) // b * b


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (bm, bn) output tile; accumulates over the K grid axis."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 128, bn: int = 128,
           bk: int = 128, interpret: bool = True) -> jnp.ndarray:
    """``a @ b`` via the tiled Pallas kernel.

    a: (m, k), b: (k, n) → (m, n). Any float dtype; accumulation happens in
    the output dtype (f32/f64 here; a TPU build would take bf16 inputs with
    an f32 accumulator, which is what ``preferred_element_type`` expresses).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {a.shape} @ {b.shape}"
    bm, bn, bk = min(bm, _ceil_to(m, 8)), min(bn, _ceil_to(n, 8)), min(bk, _ceil_to(k, 8))
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    ap, bp = _pad2(a, mp, kp), _pad2(b, kp, np_)

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        interpret=interpret,
    )(ap, bp)
    return out[:m, :n]


def matmul_at_b(a: jnp.ndarray, b: jnp.ndarray, **kw) -> jnp.ndarray:
    """``aᵀ @ b`` — explicit transpose feeds the same streaming kernel."""
    return matmul(a.T, b, **kw)
