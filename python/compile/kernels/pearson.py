"""L1 Pallas kernel: per-target Pearson correlation.

Scores each brain target independently (the paper's encoding accuracy,
Figs. 4–5, and the per-(λ, target) validation score of Algorithm 1).

The grid tiles the target axis; the time axis streams through in blocks
while five running sums (Σŷ, Σy, Σŷ², Σy², Σŷy) accumulate into a (5, t)
moments output that stays VMEM-resident per target tile. One pass over
both inputs, no materialized centered copies — the memory-bound analogue
of the fused Gram kernel. The O(t) finalization (covariance → r) happens
in plain jnp outside the kernel where XLA fuses it into a single
elementwise loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .gemm import _ceil_to, _pad2


def _moments_kernel(yh_ref, y_ref, acc_ref, *, n_rows, bn):
    """Grid (T/bt, N/bn): accumulate the five moment sums per target."""
    nn = pl.program_id(1)

    @pl.when(nn == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    yh = yh_ref[...]
    y = y_ref[...]
    # Mask padded rows out of the moments (padded cols are sliced off later).
    row = jax.lax.broadcasted_iota(jnp.int32, yh.shape, 0) + nn * bn
    valid = (row < n_rows).astype(yh.dtype)
    yh = yh * valid
    y = y * valid

    acc_ref[0, :] += jnp.sum(yh, axis=0)
    acc_ref[1, :] += jnp.sum(y, axis=0)
    acc_ref[2, :] += jnp.sum(yh * yh, axis=0)
    acc_ref[3, :] += jnp.sum(y * y, axis=0)
    acc_ref[4, :] += jnp.sum(yh * y, axis=0)


@functools.partial(jax.jit, static_argnames=("bt", "bn", "interpret"))
def pearson(yhat: jnp.ndarray, y: jnp.ndarray, *, bt: int = 256,
            bn: int = 256, interpret: bool = True) -> jnp.ndarray:
    """Column-wise Pearson r; yhat, y: (n, t) → (t,)."""
    n, t = yhat.shape
    assert y.shape == yhat.shape
    bt = min(bt, _ceil_to(t, 8))
    bn = min(bn, _ceil_to(n, 8))
    tp, np_ = _ceil_to(t, bt), _ceil_to(n, bn)
    yhp, yp = _pad2(yhat, np_, tp), _pad2(y, np_, tp)

    kernel = functools.partial(_moments_kernel, n_rows=n, bn=bn)
    acc = pl.pallas_call(
        kernel,
        grid=(tp // bt, np_ // bn),
        in_specs=[
            pl.BlockSpec((bn, bt), lambda j, nn: (nn, j)),
            pl.BlockSpec((bn, bt), lambda j, nn: (nn, j)),
        ],
        out_specs=pl.BlockSpec((5, bt), lambda j, nn: (0, j)),
        out_shape=jax.ShapeDtypeStruct((5, tp), yhat.dtype),
        interpret=interpret,
    )(yhp, yp)

    acc = acc[:, :t]
    nf = jnp.asarray(n, yhat.dtype)
    s_yh, s_y, s_yh2, s_y2, s_yhy = (acc[i] for i in range(5))
    cov = s_yhy - s_yh * s_y / nf
    var_yh = s_yh2 - s_yh * s_yh / nf
    var_y = s_y2 - s_y * s_y / nf
    return cov / (jnp.sqrt(var_yh * var_y) + 1e-12)
