"""L1 Pallas kernel: fused Gram-chunk accumulator.

Computes the two sufficient statistics of the multi-target ridge solve for
one row-chunk of the design matrix in a single pass over ``X``:

    K = XᵀX   (p×p)      C = XᵀY   (p×t)

Fusing both products means each ``X`` tile is loaded from HBM once and
reused for both accumulations while resident in VMEM — on TPU this halves
the bandwidth of the dominant O(np²) term; the same loop structure is what
MKL's ``syrk`` exploits on CPU caches (paper §2.3.3).

The rust coordinator streams row-chunks through this kernel and sums the
partial (K, C) pairs, which keeps resident memory bounded no matter how
many time samples the fMRI dataset has (Table 1's 69k rows).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .gemm import _ceil_to, _pad2


def _syrk_kernel(x_ref, xc_ref, k_ref):
    """K tile (bp, bp) at grid (i, j, nn): accumulate X_iᵀ X_j over rows."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        k_ref[...] = jnp.zeros_like(k_ref)

    k_ref[...] += jnp.dot(
        x_ref[...].T, xc_ref[...], preferred_element_type=k_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("bp", "bn", "interpret"))
def syrk(x: jnp.ndarray, *, bp: int = 128, bn: int = 128,
         interpret: bool = True) -> jnp.ndarray:
    """``XᵀX`` for x: (n, p) → (p, p) via a row-streaming Pallas kernel."""
    n, p = x.shape
    bp = min(bp, _ceil_to(p, 8))
    bn = min(bn, _ceil_to(n, 8))
    pp, np_ = _ceil_to(p, bp), _ceil_to(n, bn)
    xp = _pad2(x, np_, pp)
    out = pl.pallas_call(
        _syrk_kernel,
        grid=(pp // bp, pp // bp, np_ // bn),
        in_specs=[
            pl.BlockSpec((bn, bp), lambda i, j, nn: (nn, i)),
            pl.BlockSpec((bn, bp), lambda i, j, nn: (nn, j)),
        ],
        out_specs=pl.BlockSpec((bp, bp), lambda i, j, nn: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pp, pp), x.dtype),
        interpret=interpret,
    )(xp, xp)
    return out[:p, :p]


def gram_chunk(x: jnp.ndarray, y: jnp.ndarray, *, interpret: bool = True):
    """(K, C) = (XᵀX, XᵀY) for one row chunk; x: (n, p), y: (n, t)."""
    from .gemm import matmul

    k = syrk(x, interpret=interpret)
    c = matmul(x.T, y, interpret=interpret)
    return k, c


def _gram_fused_kernel(x_ref, y_ref, k_ref, c_ref):
    """Fused single-pass variant for p <= bp: grid (t/bt, n/bn)."""
    j, nn = pl.program_id(0), pl.program_id(1)

    @pl.when(nn == 0)
    def _init():
        @pl.when(j == 0)
        def _k():
            k_ref[...] = jnp.zeros_like(k_ref)

        c_ref[...] = jnp.zeros_like(c_ref)

    xt = x_ref[...].T

    @pl.when(j == 0)
    def _acc_k():
        k_ref[...] += jnp.dot(xt, x_ref[...], preferred_element_type=k_ref.dtype)

    c_ref[...] += jnp.dot(xt, y_ref[...], preferred_element_type=c_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "bn", "interpret"))
def gram_chunk_fused(x: jnp.ndarray, y: jnp.ndarray, *, bt: int = 256,
                     bn: int = 128, interpret: bool = True):
    """Single-pass (K, C) when the whole feature dim fits one VMEM tile.

    x: (n, p), y: (n, t) with p small enough that a (bn, p) panel plus a
    (p, p) accumulator fit VMEM (p ≤ ~512 in f32 — the ROI-scale presets).
    """
    n, p = x.shape
    n2, t = y.shape
    assert n == n2
    bn = min(bn, _ceil_to(n, 8))
    bt = min(bt, _ceil_to(t, 8))
    np_, tp = _ceil_to(n, bn), _ceil_to(t, bt)
    xp, yp = _pad2(x, np_, p), _pad2(y, np_, tp)
    k, c = pl.pallas_call(
        _gram_fused_kernel,
        grid=(tp // bt, np_ // bn),
        in_specs=[
            pl.BlockSpec((bn, p), lambda j, nn: (nn, 0)),
            pl.BlockSpec((bn, bt), lambda j, nn: (nn, j)),
        ],
        out_specs=[
            pl.BlockSpec((p, p), lambda j, nn: (0, 0)),
            pl.BlockSpec((p, bt), lambda j, nn: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, p), x.dtype),
            jax.ShapeDtypeStruct((p, tp), x.dtype),
        ],
        interpret=interpret,
    )(xp, yp)
    return k, c[:, :t]
