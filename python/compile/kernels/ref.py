"""Pure-jnp reference oracles for every L1 Pallas kernel.

These are the correctness ground truth: pytest sweeps shapes/dtypes with
hypothesis and asserts `assert_allclose(kernel(...), ref(...))`. They are
also exported through `aot.py --flavor ref` as an XLA-native (non-Pallas)
variant of each artifact, used by the rust perf pass to compare the
interpret-mode Pallas lowering against plain-HLO compute.
"""
from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Plain matrix product ``a @ b``."""
    return a @ b


def gram_ref(x: jnp.ndarray, y: jnp.ndarray):
    """Partial Gram accumulators for one row-chunk of the design matrix.

    Returns ``(K, C) = (XᵀX, XᵀY)`` — the two sufficient statistics of the
    ridge solve. The rust coordinator sums these across row chunks, which
    is exactly the streaming formulation used to bound resident memory.
    """
    return x.T @ x, x.T @ y


def ridge_weights_ref(v: jnp.ndarray, e: jnp.ndarray, z: jnp.ndarray,
                      lam) -> jnp.ndarray:
    """``W_λ = V diag(1/(e+λ)) Z`` for a single λ.

    ``V, e`` are the eigendecomposition of the Gram matrix ``K = V E Vᵀ``
    and ``Z = Vᵀ XᵀY``; this is the paper's Eq. 5 rewritten through the
    Gram eigenbasis (see DESIGN.md §2).
    """
    d = 1.0 / (e + lam)
    return v @ (d[:, None] * z)


def lambda_sweep_ref(a: jnp.ndarray, e: jnp.ndarray, z: jnp.ndarray,
                     lambdas: jnp.ndarray) -> jnp.ndarray:
    """Multi-λ scaled matmul: ``out[i] = A @ (diag(1/(e+λ_i)) Z)``.

    With ``A = X_val V`` this yields validation predictions for every λ in
    one pass — the paper's "compute the decomposition once, reuse across r
    hyper-parameters" trick. Shape: (r, m, t).
    """
    d = 1.0 / (e[None, :] + lambdas[:, None])          # (r, p)
    return jnp.einsum("mp,rp,pt->rmt", a, d, z)


def pearson_ref(yhat: jnp.ndarray, y: jnp.ndarray,
                eps: float = 1e-12) -> jnp.ndarray:
    """Column-wise Pearson correlation between prediction and target.

    Returns one r per brain target (the paper's encoding score, Fig. 4/5).
    """
    yh = yhat - yhat.mean(axis=0, keepdims=True)
    yc = y - y.mean(axis=0, keepdims=True)
    num = (yh * yc).sum(axis=0)
    den = jnp.sqrt((yh * yh).sum(axis=0) * (yc * yc).sum(axis=0))
    return num / (den + eps)


def sweep_scores_ref(a: jnp.ndarray, e: jnp.ndarray, z: jnp.ndarray,
                     yval: jnp.ndarray, lambdas: jnp.ndarray) -> jnp.ndarray:
    """Validation Pearson score per (λ, target): shape (r, t)."""
    preds = lambda_sweep_ref(a, e, z, lambdas)          # (r, nv, t)
    return jnp.stack(
        [pearson_ref(preds[i], yval) for i in range(preds.shape[0])]
    )
