"""L1 Pallas kernel: the multi-λ ridge sweep — the paper's compute hot-spot.

Given the Gram eigenbasis (V, e) and the projected cross-covariance
``Z = Vᵀ XᵀY``, ridge solutions for *all* r candidate λ are scaled matmuls
sharing the same operands:

    W_λ = V · (Z ⊘ (e + λ))            (final weights,     A := V)
    Ŷ_λ = (X_val V) · (Z ⊘ (e + λ))    (validation preds,  A := X_val V)

This kernel runs the whole λ grid in one launch with a 4-D grid
(r, M/bm, T/bt, P/bk): the λ axis is the *outermost* grid dimension so the
A-panel and Z-panel schedule is identical for every λ — on TPU the panels
stay VMEM-resident across the λ axis and only the per-λ diagonal scale
``d = 1/(e+λ)`` (r×p, tiny) changes. This is exactly the paper's
"decompose once, reuse across r hyper-parameters" insight (§2.3.1 / Eq. 5)
expressed as an HBM↔VMEM schedule instead of scikit-learn's loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .gemm import _ceil_to, _pad2


def _sweep_kernel(d_ref, a_ref, z_ref, o_ref):
    """One (bm, bt) tile of W_λ / Ŷ_λ for λ index = program_id(0)."""

    @pl.when(pl.program_id(3) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # d_ref block is (1, bk): the slice of 1/(e+λ_r) for this K panel.
    scaled = z_ref[...] * d_ref[0, :][:, None]          # (bk, bt)
    o_ref[...] += jnp.dot(
        a_ref[...], scaled, preferred_element_type=o_ref.dtype
    )


@functools.partial(
    jax.jit, static_argnames=("bm", "bt", "bk", "interpret")
)
def lambda_sweep(a: jnp.ndarray, e: jnp.ndarray, z: jnp.ndarray,
                 lambdas: jnp.ndarray, *, bm: int = 128, bt: int = 128,
                 bk: int = 128, interpret: bool = True) -> jnp.ndarray:
    """out[i] = A @ (diag(1/(e+λ_i)) Z)  for every λ_i.

    a: (m, p), e: (p,), z: (p, t), lambdas: (r,) → (r, m, t).
    """
    m, p = a.shape
    p2, t = z.shape
    assert p == p2
    r = lambdas.shape[0]
    d = 1.0 / (e[None, :] + lambdas[:, None])           # (r, p)

    bm = min(bm, _ceil_to(m, 8))
    bt = min(bt, _ceil_to(t, 8))
    bk = min(bk, _ceil_to(p, 8))
    mp, tp, pp = _ceil_to(m, bm), _ceil_to(t, bt), _ceil_to(p, bk)
    ap, zp = _pad2(a, mp, pp), _pad2(z, pp, tp)
    dp = _pad2(d, r, pp)

    out = pl.pallas_call(
        _sweep_kernel,
        grid=(r, mp // bm, tp // bt, pp // bk),
        in_specs=[
            pl.BlockSpec((1, bk), lambda li, i, j, kk: (li, kk)),
            pl.BlockSpec((bm, bk), lambda li, i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bt), lambda li, i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bt), lambda li, i, j, kk: (li, i, j)),
        out_shape=jax.ShapeDtypeStruct((r, mp, tp), a.dtype),
        interpret=interpret,
    )(dp, ap, zp)
    return out[:, :m, :t]


def ridge_weights(v: jnp.ndarray, e: jnp.ndarray, z: jnp.ndarray,
                  lam: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """Final weights for a single (already selected) λ: (p, t).

    Reuses the sweep kernel with a length-1 λ grid so the hot path has a
    single compiled GEMM schedule.
    """
    lam_arr = jnp.reshape(lam, (1,)).astype(v.dtype)
    return lambda_sweep(v, e, z, lam_arr, interpret=interpret)[0]
