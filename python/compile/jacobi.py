"""L2 substrate: parallel-ordering Jacobi eigendecomposition in pure jnp/lax.

Why this exists: the paper's decompose-once/reuse-across-λ trick needs an
orthogonal eigendecomposition of the Gram matrix ``K = XᵀX = V E Vᵀ``
(equivalent to the SVD of X for ridge purposes — same reuse, see DESIGN.md
§2). But ``jnp.linalg.{svd,eigh}`` lower on CPU to LAPACK *custom calls*
registered by jaxlib, which the rust PJRT client (xla_extension 0.5.1)
cannot execute. So we implement the eigensolver from scratch with core HLO
ops only, keeping the whole AOT graph loadable from rust.

Algorithm: **parallel Jacobi** with the round-robin ("chess tournament")
schedule. Each sweep visits all p(p−1)/2 index pairs as (p−1) rounds of
p/2 *disjoint* rotations; disjoint rotations commute, so a whole round is
applied as one vectorized update — O(p) sequential steps per sweep instead
of O(p²), which keeps the lax.fori_loop tractable.

IMPLEMENTATION NOTE: the round update is expressed as
    permute rows/cols so pairs are (k, k+p/2) → slice-halves arithmetic →
    concat → inverse permute
with no scatters and no multi-coordinate gathers. Historical context: the
original gather/scatter formulation appeared to miscompile under the rust
PJRT client; bisection eventually traced the failures to the HLO-text
printer *eliding large constants* (the round-robin schedule parsed back as
zeros — fixed in aot.py with print_large_constants). The permutation form
was written during that hunt and is kept: it is equally fast, verified
end-to-end against the rust client at p ∈ {8, 128, 512}, and structurally
simpler for the XLA while-loop (pure slice/concat dataflow).

Convergence: quadratic once sweeps start; `sweeps=10` drives the off-norm
of random SPD matrices below f64 roundoff for p ≤ 2048 (property-tested in
python/tests/test_jacobi.py).
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax


def round_robin_schedule(p: int) -> np.ndarray:
    """Round-robin pairings: (p-1 rounds, 2, p/2) index array, p even.

    Standard circle method: player 0 stays fixed, the others rotate one
    seat per round; every unordered pair (i, j) appears exactly once per
    p-1 rounds.
    """
    assert p % 2 == 0
    arr = list(range(p))
    rounds = []
    for _ in range(p - 1):
        top = [arr[i] for i in range(p // 2)]
        bot = [arr[p - 1 - i] for i in range(p // 2)]
        lo = [min(a, b) for a, b in zip(top, bot)]
        hi = [max(a, b) for a, b in zip(top, bot)]
        rounds.append([lo, hi])
        arr = [arr[0]] + [arr[-1]] + arr[1:-1]
    return np.asarray(rounds, dtype=np.int32)  # (p-1, 2, p/2)


def permutation_schedule(p: int) -> tuple[np.ndarray, np.ndarray]:
    """Per-round permutations derived from the round-robin pairings.

    Returns (perm, inv) of shape (p-1, p): applying ``perm[r]`` reorders
    indices so round r's pairs sit at (k, k + p/2); ``inv[r]`` undoes it.
    """
    sched = round_robin_schedule(p)
    rounds = sched.shape[0]
    perm = np.zeros((rounds, p), dtype=np.int32)
    inv = np.zeros((rounds, p), dtype=np.int32)
    h = p // 2
    for r in range(rounds):
        lo, hi = sched[r, 0], sched[r, 1]
        perm[r, :h] = lo
        perm[r, h:] = hi
        inv[r, perm[r]] = np.arange(p, dtype=np.int32)
    return perm, inv


def _strided_diag(flat: jnp.ndarray, start: int, stride: int,
                  count: int) -> jnp.ndarray:
    """count elements of `flat` from `start` with `stride`, as a slice.

    Equivalent to ``jnp.diagonal`` (which lowers to a 2-coordinate gather);
    a strided ``lax.slice`` keeps the loop body to the simplest core ops,
    which proved easiest to validate through the HLO-text roundtrip into
    the rust PJRT client.
    """
    return lax.slice(flat, (start,), (start + (count - 1) * stride + 1,),
                     (stride,))


def _round_update(a: jnp.ndarray, v: jnp.ndarray, perm: jnp.ndarray,
                  inv: jnp.ndarray):
    """Apply one round of p/2 disjoint rotations via permute/slice/concat."""
    p = a.shape[0]
    h = p // 2

    # Permute so pair k is (k, k+h).
    ap = jnp.take(jnp.take(a, perm, axis=0), perm, axis=1)

    # Materialization fence: keep the simplifier from fusing slices into
    # the gather chain (miscompiles on xla_extension 0.5.1, bisected).
    ap = lax.optimization_barrier(ap)

    # Diagonals via strided slices of the flattened matrix (NOT
    # jnp.diagonal — see _strided_diag).
    flat = ap.reshape((p * p,))
    a_ii = _strided_diag(flat, 0, p + 1, h)
    a_jj = _strided_diag(flat, h * (p + 1), p + 1, h)
    a_ij = _strided_diag(flat, h, p + 1, h)  # ap[k, k+h]

    # Stable rotation angle zeroing a_ij (Golub & Van Loan §8.5.2).
    small = jnp.abs(a_ij) <= 1e-300
    tau = (a_jj - a_ii) / (2.0 * jnp.where(small, 1.0, a_ij))
    t = jnp.sign(tau) / (jnp.abs(tau) + jnp.sqrt(1.0 + tau * tau))
    t = jnp.where(small, 0.0, t)
    c = 1.0 / jnp.sqrt(1.0 + t * t)
    s = t * c

    # Second fence: (c, s) feed both loop-carried outputs (A and V); the
    # shared values must materialize before either consumer runs.
    ap, c, s = lax.optimization_barrier((ap, c, s))

    # Row mix: rows k and k+h.
    top, bot = ap[:h, :], ap[h:, :]
    ap = jnp.concatenate(
        [c[:, None] * top - s[:, None] * bot,
         s[:, None] * top + c[:, None] * bot], axis=0)
    # Column mix.
    left, right = ap[:, :h], ap[:, h:]
    ap = jnp.concatenate(
        [left * c[None, :] - right * s[None, :],
         left * s[None, :] + right * c[None, :]], axis=1)

    # Un-permute.
    a_new = jnp.take(jnp.take(ap, inv, axis=0), inv, axis=1)

    # Accumulate eigenvectors: V ← VJ (column mix in permuted space).
    vp = jnp.take(v, perm, axis=1)
    vleft, vright = vp[:, :h], vp[:, h:]
    vp = jnp.concatenate(
        [vleft * c[None, :] - vright * s[None, :],
         vleft * s[None, :] + vright * c[None, :]], axis=1)
    v_new = jnp.take(vp, inv, axis=1)
    return a_new, v_new


@functools.partial(jax.jit, static_argnames=("sweeps",))
def jacobi_eigh(k: jnp.ndarray, *, sweeps: int = 12):
    """Eigendecomposition of a symmetric matrix: ``K = V diag(e) Vᵀ``.

    Returns (e ascending, V with matching columns). Pure HLO — safe to AOT
    for the rust runtime. Odd p is padded with a zero border (the padded
    eigenpair is sliced away afterwards).
    """
    p0 = k.shape[0]
    assert k.shape == (p0, p0)
    pad = p0 % 2
    p = p0 + pad
    if pad:
        k = jnp.pad(k, ((0, 1), (0, 1)))

    perm_np, inv_np = permutation_schedule(p)
    perm_all = jnp.asarray(perm_np)  # (p-1, p)
    inv_all = jnp.asarray(inv_np)
    rounds = perm_all.shape[0]
    v0 = jnp.eye(p, dtype=k.dtype)

    def body(step, carry):
        a, v = carry
        r = step % rounds
        perm = lax.dynamic_index_in_dim(perm_all, r, 0, keepdims=False)
        inv = lax.dynamic_index_in_dim(inv_all, r, 0, keepdims=False)
        return _round_update(a, v, perm, inv)

    a, v = lax.fori_loop(0, sweeps * rounds, body, (k, v0))
    a = lax.optimization_barrier(a)
    e = _strided_diag(a.reshape((p * p,)), 0, p + 1, p)

    order = jnp.argsort(e)
    e = jnp.take(e, order)
    v = jnp.take(v, order, axis=1)
    if pad:
        # Drop the synthetic zero eigenpair introduced by padding: it is
        # the one whose eigenvector has all its mass on the padded
        # coordinate.
        mass = jnp.abs(v[p0, :])
        drop = jnp.argmax(mass)
        keep = jnp.where(jnp.arange(p) < drop, jnp.arange(p), jnp.arange(p) + 1)[: p0]
        e = jnp.take(e, keep)
        v = jnp.take(v, keep, axis=1)[:p0, :]
    return e, v


def offdiag_norm(a: jnp.ndarray) -> jnp.ndarray:
    """Frobenius norm of the off-diagonal part (convergence diagnostic)."""
    return jnp.sqrt(jnp.sum(a * a) - jnp.sum(jnp.diagonal(a) ** 2))
