"""AOT driver: lower the L2 graph to HLO text artifacts for the rust runtime.

Interchange format is **HLO text**, not serialized HloModuleProto: jax ≥0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` rust crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Each artifact is one (function × shape preset) pair. ``manifest.json``
records, per artifact: entry name, file, input/output shapes + dtypes, and
the preset parameters — the rust `runtime::Manifest` is generated from it.

Loadability invariant: emitted HLO must contain **no custom-call** (LAPACK
etc.); `--check` greps for it and fails the build, and pytest enforces it
too (test_aot.py).

Usage:
    python -m compile.aot --out ../artifacts [--presets small,main] [--flavor pallas|ref]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

F64 = jnp.float64
F32 = jnp.float32

# ---------------------------------------------------------------------------
# Shape presets.
#
# n_chunk:  rows per streaming gram/predict chunk
# p:        feature dimension (paper: 16384; scaled, see DESIGN.md §3)
# t_chunk:  brain targets per batch-chunk (B-MOR batches are multiples)
# nv:       validation rows per chunk
# r:        λ grid size (paper: 11)
# sweeps:   Jacobi sweeps
# ---------------------------------------------------------------------------
# NOTE: feat_dim × window(4) == p, so the frames→features→window→ridge
# chain composes shape-exactly (examples/full_pipeline.rs).
PRESETS = {
    "small": dict(n_chunk=256, p=128, t_chunk=256, nv=128, r=11, sweeps=10,
                  feat_batch=32, feat_dim=32),
    "main": dict(n_chunk=1024, p=512, t_chunk=1024, nv=512, r=11, sweeps=10,
                 feat_batch=64, feat_dim=128),
}

LAMBDAS = jnp.asarray(model.LAMBDA_GRID, dtype=F64)


def spec(shape, dtype=F64):
    return jax.ShapeDtypeStruct(shape, dtype)


def entries_for(preset_name: str, cfg: dict, pallas: bool):
    """The artifact list for one preset: (name, fn, example_args)."""
    n, p, t, nv, r = (cfg["n_chunk"], cfg["p"], cfg["t_chunk"], cfg["nv"],
                      cfg["r"])
    sweeps = cfg["sweeps"]
    fb, fd = cfg["feat_batch"], cfg["feat_dim"]
    tag = preset_name

    def gram(x, y):
        return model.gram_fn(x, y, pallas=pallas)

    def eigh(k):
        return model.eigh_fn(k, sweeps=sweeps)

    def prep(v, c, xval):
        return model.prep_fn(v, c, xval, pallas=pallas)

    def sweep(a, e, z, yval, lams):
        return (model.sweep_fn(a, e, z, yval, lams, pallas=pallas),)

    def solve(v, e, z, lam):
        return (model.solve_fn(v, e, z, lam[0], pallas=pallas),)

    def predict(x, w):
        return (model.predict_fn(x, w, pallas=pallas),)

    def pearson(yhat, y):
        return (model.pearson_fn(yhat, y, pallas=pallas),)

    def features(frames):
        return (model.features_fn(frames, feat_dim=fd),)

    def fit_fused(xtr, ytr, xval, yval, lams):
        return model.fit_fused_fn(xtr, ytr, xval, yval, lams,
                                  sweeps=sweeps, pallas=pallas)

    ents = [
        (f"gram_{tag}", gram, (spec((n, p)), spec((n, t)))),
        (f"eigh_{tag}", eigh, (spec((p, p)),)),
        (f"prep_{tag}", prep, (spec((p, p)), spec((p, t)), spec((nv, p)))),
        (f"sweep_{tag}", sweep,
         (spec((nv, p)), spec((p,)), spec((p, t)), spec((nv, t)), spec((r,)))),
        (f"solve_{tag}", solve,
         (spec((p, p)), spec((p,)), spec((p, t)), spec((1,)))),
        (f"predict_{tag}", predict, (spec((n, p)), spec((p, t)))),
        (f"pearson_{tag}", pearson, (spec((n, t)), spec((n, t)))),
        (f"features_{tag}", features, (spec((fb, 32, 32, 3), F32),)),
    ]
    if preset_name == "small":
        ents.append((f"fit_fused_{tag}", fit_fused,
                     (spec((n, p)), spec((n, t)), spec((nv, p)),
                      spec((nv, t)), spec((r,)))))
    return ents


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True).

    CRITICAL: print with `print_large_constants=True`. The default HLO
    printer elides big literals as `constant(...)`; the text parser in the
    rust client then silently materializes ZEROS for them (bisected via
    the Jacobi schedule constant — DESIGN.md §Runtime gotchas).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions.short_parsable()
    opts.print_large_constants = True
    return comp.get_hlo_module().to_string(opts)


def shape_info(x) -> dict:
    return {"shape": list(x.shape), "dtype": str(x.dtype)}


def lower_entry(name, fn, args, out_dir, check=True):
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    if check and "custom-call" in text:
        raise RuntimeError(
            f"artifact {name} contains a custom-call — not loadable by the "
            "rust PJRT client. Offending op must be replaced by a pure-HLO "
            "substrate (see DESIGN.md §2)."
        )
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    outs = jax.eval_shape(fn, *args)
    outs = outs if isinstance(outs, (tuple, list)) else (outs,)
    return {
        "name": name,
        "file": fname,
        "inputs": [shape_info(a) for a in args],
        "outputs": [shape_info(o) for o in outs],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--presets", default="small,main")
    ap.add_argument("--flavor", default="pallas", choices=["pallas", "ref"],
                    help="pallas: L1 kernels; ref: plain-jnp lowering "
                         "(perf-pass comparator)")
    ap.add_argument("--no-check", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    pallas = args.flavor == "pallas"
    suffix = "" if pallas else "_ref"
    manifest = {
        "format": 1,
        "flavor": args.flavor,
        "lambda_grid": [float(x) for x in model.LAMBDA_GRID],
        "presets": {},
        "entries": [],
    }
    for pname in args.presets.split(","):
        cfg = PRESETS[pname]
        manifest["presets"][pname] = cfg
        for name, fn, eargs in entries_for(pname, cfg, pallas):
            name = name + suffix
            print(f"[aot] lowering {name} ...", flush=True)
            info = lower_entry(name, fn, eargs, args.out,
                               check=not args.no_check)
            info["preset"] = pname
            manifest["entries"].append(info)

    man_path = os.path.join(
        args.out, "manifest.json" if pallas else "manifest_ref.json"
    )
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote {len(manifest['entries'])} artifacts + {man_path}")


if __name__ == "__main__":
    sys.exit(main())
