"""L2 substrate: VGG16-surrogate visual feature extractor.

The paper feeds movie frames through TensorFlow's ImageNet-pretrained VGG16
and keeps the 4096-d FC2 activations (Appendix 7.1). Neither the weights
nor the Friends frames are redistributable, so we substitute a *fixed,
deterministic* convolutional network with the same role: a frozen nonlinear
map from frame pixels to a feature vector that the ridge model regresses
brain activity onto (see DESIGN.md §3 — only the feature map's dimension
and fixedness matter to the scaling study).

Architecture (VGG-style, scaled to 32×32 frames):
    conv3x3(3→16) ReLU → maxpool2
    conv3x3(16→32) ReLU → maxpool2
    conv3x3(32→64) ReLU → maxpool2
    flatten → dense(1024→feat_dim) tanh

Weights are generated once from a fixed PRNG seed (He-scaled), so python
and rust agree on the mapping forever without shipping checkpoint files.
Everything lowers to core HLO (conv, reduce-window, dot) — loadable from
the rust PJRT client.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

FRAME = 32          # input frames are FRAME×FRAME×3
CHANNELS = (16, 32, 64)
SEED = 1337


def init_params(feat_dim: int, dtype=jnp.float32):
    """Deterministic frozen weights (He init, fixed seed)."""
    key = jax.random.PRNGKey(SEED)
    params = {}
    cin = 3
    for li, cout in enumerate(CHANNELS):
        key, k1 = jax.random.split(key)
        fan_in = 3 * 3 * cin
        params[f"conv{li}"] = (
            jax.random.normal(k1, (3, 3, cin, cout), dtype)
            * jnp.sqrt(2.0 / fan_in)
        )
        cin = cout
    spatial = FRAME // (2 ** len(CHANNELS))
    flat = spatial * spatial * CHANNELS[-1]
    key, k2 = jax.random.split(key)
    params["dense"] = (
        jax.random.normal(k2, (flat, feat_dim), dtype) * jnp.sqrt(1.0 / flat)
    )
    return params


def _maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def extract_features(frames: jnp.ndarray, params) -> jnp.ndarray:
    """frames: (b, 32, 32, 3) float32 → (b, feat_dim).

    Output is tanh-bounded and then standardized per feature batch by the
    caller (the rust pipeline z-scores features over time, mirroring the
    paper's per-run normalization).
    """
    x = frames
    for li in range(len(CHANNELS)):
        w = params[f"conv{li}"]
        x = lax.conv_general_dilated(
            x, w, window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        x = jnp.maximum(x, 0.0)
        x = _maxpool2(x)
    b = x.shape[0]
    x = x.reshape(b, -1)
    return jnp.tanh(x @ params["dense"])


@functools.partial(jax.jit, static_argnames=("feat_dim",))
def features_fn(frames: jnp.ndarray, *, feat_dim: int = 256) -> jnp.ndarray:
    """Jit-able closure with frozen params baked in as constants."""
    params = init_params(feat_dim, frames.dtype)
    return extract_features(frames, params)
