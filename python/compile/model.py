"""L2 model: the brain-encoding compute graph.

Composes the L1 Pallas kernels (gram, gemm, λ-sweep, pearson) and the L2
substrates (Jacobi eigh, feature extractor) into the exact set of functions
the rust coordinator calls on its hot path. Each function here is AOT-
lowered by ``aot.py`` to one HLO artifact per shape preset; python never
runs at serving/benchmark time.

The decomposition into stages mirrors Algorithm 1 of the paper:

    gram_fn        — streaming sufficient statistics  (K, C) += (XᵀX, XᵀY)
    eigh_fn        — K = V E Vᵀ               (once per CV split)
    prep_fn        — Z = VᵀC,  A = X_val V    (once per split)
    sweep_fn       — scores[r, t] for the whole λ grid (Pallas hot-spot)
    solve_fn       — W = V (Z ⊘ (e+λ*))       (once, after λ* selection)
    predict_fn     — Ŷ = X W                  (test-time)
    pearson_fn     — per-target r             (scoring)
    features_fn    — frames → stimulus features (VGG16 surrogate)

λ* selection (argmax of mean score) happens in rust: it is O(r·t) scalar
work, inherently serial, and the paper's Algorithm 1 line 13.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .jacobi import jacobi_eigh
from .kernels.gemm import matmul
from .kernels.gram import gram_chunk, gram_chunk_fused
from .kernels.pearson import pearson
from .kernels.ridge_sweep import lambda_sweep, ridge_weights
from .kernels import ref
from . import features as feat

# The paper's λ grid (§2.2.4).
LAMBDA_GRID = (0.1, 1, 100, 200, 300, 400, 600, 800, 900, 1000, 1200)


def gram_fn(x, y, *, pallas=True):
    """One row-chunk of sufficient statistics: (K, C) = (XᵀX, XᵀY)."""
    if not pallas:
        return ref.gram_ref(x, y)
    p = x.shape[1]
    if p <= 512:
        return gram_chunk_fused(x, y)
    return gram_chunk(x, y)


def eigh_fn(k, *, sweeps=10):
    """Gram eigendecomposition K = V diag(e) Vᵀ (ascending e)."""
    e, v = jacobi_eigh(k, sweeps=sweeps)
    return e, v


def prep_fn(v, c, xval, *, pallas=True):
    """Per-split projections: Z = VᵀC and A = X_val V."""
    mm = matmul if pallas else ref.matmul_ref
    z = mm(v.T, c)
    a = mm(xval, v)
    return z, a


def sweep_fn(a, e, z, yval, lambdas, *, pallas=True):
    """Validation Pearson score for every (λ, target): (r, t).

    The multi-λ scaled matmul is the Pallas hot-spot; scoring streams each
    λ's predictions through the pearson kernel.
    """
    if not pallas:
        return ref.sweep_scores_ref(a, e, z, yval, lambdas)
    preds = lambda_sweep(a, e, z, lambdas)          # (r, nv, t)
    r = preds.shape[0]
    return jnp.stack([pearson(preds[i], yval) for i in range(r)])


def solve_fn(v, e, z, lam, *, pallas=True):
    """Final ridge weights W = V (Z ⊘ (e+λ*)): (p, t)."""
    if not pallas:
        return ref.ridge_weights_ref(v, e, z, lam)
    return ridge_weights(v, e, z, lam)


def predict_fn(x, w, *, pallas=True):
    """Test-set predictions Ŷ = XW."""
    mm = matmul if pallas else ref.matmul_ref
    return mm(x, w)


def pearson_fn(yhat, y, *, pallas=True):
    """Per-target encoding score."""
    if not pallas:
        return ref.pearson_ref(yhat, y)
    return pearson(yhat, y)


def features_fn(frames, *, feat_dim=256):
    """Stimulus frames → feature vectors (frozen VGG16 surrogate)."""
    return feat.features_fn(frames, feat_dim=feat_dim)


# ---------------------------------------------------------------------------
# Fused single-call fit for small problems (quickstart / tests): runs the
# entire Algorithm-1 inner loop — gram, eigh, sweep, shared-λ* selection,
# final solve — inside one XLA program. Used by the rust `validate` command
# to cross-check the staged path against a single-graph execution.
# ---------------------------------------------------------------------------

def fit_fused_fn(xtr, ytr, xval, yval, lambdas, *, sweeps=10, pallas=True):
    """Returns (scores (r,t), best λ index (scalar int32), W (p,t))."""
    k, c = gram_fn(xtr, ytr, pallas=pallas)
    e, v = eigh_fn(k, sweeps=sweeps)
    z, a = prep_fn(v, c, xval, pallas=pallas)
    scores = sweep_fn(a, e, z, yval, lambdas, pallas=pallas)
    mean_scores = jnp.mean(scores, axis=1)              # shared λ (paper §2.2.4)
    best = jnp.argmax(mean_scores).astype(jnp.int32)
    lam = jnp.take(lambdas, best)
    w = solve_fn(v, e, z, lam, pallas=pallas)
    return scores, best, w


def ridge_closed_form_ref(xtr, ytr, lam):
    """Direct (XᵀX+λI)⁻¹XᵀY via jnp.linalg.solve — test-only oracle.

    Never AOT'd (solve lowers to a LAPACK custom call); used by pytest to
    pin the whole eigh-based path against the textbook formulation.
    """
    p = xtr.shape[1]
    k = xtr.T @ xtr + lam * jnp.eye(p, dtype=xtr.dtype)
    return jnp.linalg.solve(k, xtr.T @ ytr)
